//! Sweep runner: executes a matrix of experiment jobs, collects uniform
//! result rows, and persists them as JSON under `target/bench_results/`.
//!
//! Jobs run either one at a time ([`Runner::run_job`], precise per-job
//! wall times) or concurrently on the execution engine's worker pool
//! ([`Runner::run_jobs_parallel`], throughput mode — rows still land in
//! submission order, so output files are deterministic).

use std::path::PathBuf;
use std::time::Instant;

use crate::api::RunSpec;
use crate::exec::pool;
use crate::methods::MethodReport;
use crate::util::json::Json;

/// One uniform result row (a line of a paper table).
#[derive(Clone, Debug)]
pub struct ExperimentRow {
    pub experiment: String,
    pub dataset: String,
    pub method: String,
    pub scheme: String,
    pub nt: usize,
    pub nfe_forward: u64,
    pub nfe_backward: u64,
    pub time_secs: f64,
    /// modeled accelerator memory (Table-2 semantics, +CUDA constant)
    pub model_mem_bytes: u64,
    /// measured checkpoint bytes in this process
    pub measured_ckpt_bytes: u64,
    /// peak bytes resident in the hot (RAM) checkpoint tier
    pub ckpt_hot_bytes: u64,
    /// bytes written to the cold (disk) checkpoint tier
    pub ckpt_cold_bytes: u64,
    /// checkpoints evicted hot → cold
    pub spill_count: u64,
    /// cold lookups served by the background prefetcher
    pub prefetch_hits: u64,
    /// cold lookups that fell back to synchronous reads
    pub cold_reads: u64,
    /// executed (accepted) steps of the forward pass
    pub n_accepted: u64,
    /// rejected adaptive trials (0 for static grids)
    pub n_rejected: u64,
    /// smallest executed step size
    pub h_min: f64,
    /// largest executed step size
    pub h_max: f64,
    /// worker threads of the data-parallel engine (0 = not data-parallel)
    pub workers: u64,
    /// batch rows per second over the forward+backward pair
    pub samples_per_sec: f64,
    /// global hot-tier pool size when an arbiter governed the run
    pub lease_pool_bytes: u64,
    /// arbiter peak leased bytes (fleet concurrent hot footprint)
    pub peak_leased_bytes: u64,
    /// clipped lease asks (arbiter contention events)
    pub lease_waits: u64,
    /// bytes of clipped grant across contended asks
    pub lease_denied_bytes: u64,
    /// peak mandatory-floor overdraw beyond the pool (0 = budget held)
    pub over_grant_bytes: u64,
    /// per-block exec stats folded into the exec columns
    /// (0 = a single, never-aggregated block)
    pub blocks_merged: u64,
    /// observed wall time per adjoint phase, `(phase, seconds)` over
    /// forward/store/restore/recompute/vjp — filled by
    /// [`ExperimentRow::attach_obs`] on observed runs, empty otherwise
    pub phase_secs: Vec<(String, f64)>,
    /// [`crate::methods::MemModel`]'s checkpoint-storage prediction for
    /// this run (observed runs; DESIGN.md §11)
    pub mem_pred_ckpt_bytes: u64,
    /// live peak checkpoint bytes seen by the obs gauges
    pub mem_obs_ckpt_bytes: u64,
    /// observed / predicted checkpoint bytes (0 when nothing attached)
    pub mem_model_ratio: f64,
    /// total GEMM multiply-adds the obs counters recorded, summed across
    /// every logical tid (pool-worker shards included — they stamp the
    /// counter through their `job_ctx` tids); 0 on unobserved runs
    pub gemm_mul_adds: u64,
    /// serving throughput of a serve-mode row (`None` on gradient rows;
    /// see [`ExperimentRow::from_serve_report`])
    pub requests_per_sec: Option<f64>,
    /// median request latency of a serve-mode row, seconds
    pub latency_p50_secs: Option<f64>,
    /// 99th-percentile request latency of a serve-mode row, seconds
    pub latency_p99_secs: Option<f64>,
    /// the requested policy of an `auto:<budget>` run (`None` when the
    /// spec named a concrete policy)
    pub policy_requested: Option<String>,
    /// the concrete policy the auto run resolved to
    pub policy_resolved: Option<String>,
    /// the full serialized [`RunSpec`] that produced this row (rows from
    /// facade-driven jobs are reproducible artifacts)
    pub run_spec: Option<Json>,
    pub extra: Vec<(String, String)>,
}

impl ExperimentRow {
    pub fn from_report(
        experiment: &str,
        dataset: &str,
        method: &str,
        scheme: &str,
        nt: usize,
        report: &MethodReport,
        time_secs: f64,
        model_mem_bytes: u64,
    ) -> Self {
        ExperimentRow {
            experiment: experiment.into(),
            dataset: dataset.into(),
            method: method.into(),
            scheme: scheme.into(),
            nt,
            nfe_forward: report.nfe_forward,
            nfe_backward: report.nfe_backward,
            time_secs,
            model_mem_bytes,
            measured_ckpt_bytes: report.ckpt_bytes,
            ckpt_hot_bytes: report.tier.peak_hot_bytes,
            ckpt_cold_bytes: report.tier.cold_bytes_written,
            spill_count: report.tier.spills,
            prefetch_hits: report.tier.prefetch_hits,
            cold_reads: report.tier.cold_reads,
            n_accepted: report.n_accepted,
            n_rejected: report.n_rejected,
            h_min: report.h_min,
            h_max: report.h_max,
            workers: report.exec.workers,
            samples_per_sec: report.exec.samples_per_sec,
            lease_pool_bytes: report.exec.lease_pool_bytes,
            peak_leased_bytes: report.exec.peak_leased_bytes,
            lease_waits: report.exec.lease_waits,
            lease_denied_bytes: report.exec.lease_denied_bytes,
            over_grant_bytes: report.exec.over_grant_bytes,
            blocks_merged: report.exec.blocks_merged,
            phase_secs: Vec::new(),
            mem_pred_ckpt_bytes: 0,
            mem_obs_ckpt_bytes: 0,
            mem_model_ratio: 0.0,
            gemm_mul_adds: 0,
            requests_per_sec: None,
            latency_p50_secs: None,
            latency_p99_secs: None,
            policy_requested: report.auto.requested_name(),
            policy_resolved: report.auto.resolved_name(),
            run_spec: None,
            extra: Vec::new(),
        }
    }

    /// Fold an obs metrics snapshot into this row: per-phase wall times
    /// plus the predicted-vs-observed checkpoint-memory comparison (the
    /// paper's Table-2 model validated on every observed run; the
    /// prediction comes from [`crate::methods::MemModel::ckpt_bytes_for`]).
    pub fn attach_obs(&mut self, m: &crate::obs::Metrics, predicted_ckpt_bytes: u64) {
        self.phase_secs = crate::obs::PHASES
            .iter()
            .filter(|p| m.span_count(p) > 0)
            .map(|p| (p.to_string(), m.span_total_secs(p)))
            .collect();
        let observed = m.gauge("ckpt.hot_bytes").max.max(m.gauge("tier.hot_bytes").max);
        self.mem_obs_ckpt_bytes = observed as u64;
        self.mem_pred_ckpt_bytes = predicted_ckpt_bytes;
        self.mem_model_ratio = if predicted_ckpt_bytes == 0 {
            0.0
        } else {
            observed / predicted_ckpt_bytes as f64
        };
        // kernel provenance: which GEMM path ran and how much work it did
        // (the counter fold already sums across logical tids, so pool
        // shards are included)
        self.extra.push((
            "kernel".to_string(),
            crate::tensor::gemm::kernel_path().name().to_string(),
        ));
        self.gemm_mul_adds = m.counter("gemm.mul_adds") as u64;
    }

    /// Row identity and embedded spec derived from a [`RunSpec`] (the
    /// method/scheme/nt columns come from the spec; `nt` is 0 for
    /// adaptive grids, whose executed count is `n_accepted`).
    pub fn from_spec_report(
        experiment: &str,
        dataset: &str,
        spec: &RunSpec,
        report: &MethodReport,
        time_secs: f64,
        model_mem_bytes: u64,
    ) -> Self {
        let mut row = ExperimentRow::from_report(
            experiment,
            dataset,
            &spec.method.name(),
            spec.scheme.name(),
            spec.grid.planned_nt().unwrap_or(0),
            report,
            time_secs,
            model_mem_bytes,
        );
        row.run_spec = Some(spec.to_json());
        row
    }

    /// Row for a forward-only serving run (DESIGN.md §15): identity
    /// columns from the spec, throughput/latency from the
    /// [`crate::serve::ServeReport`], exec columns from the fleet's
    /// summed stats.  Gradient-only columns stay zero; downstream
    /// consumers (`pnode report`) recognize a serve row by its
    /// `requests_per_sec` field.
    pub fn from_serve_report(
        experiment: &str,
        dataset: &str,
        spec: &RunSpec,
        rep: &crate::serve::ServeReport,
        time_secs: f64,
    ) -> Self {
        let mut row = ExperimentRow::from_spec_report(
            experiment,
            dataset,
            spec,
            &MethodReport::default(),
            time_secs,
            0,
        );
        row.workers = rep.exec.workers;
        row.samples_per_sec = rep.exec.samples_per_sec;
        row.lease_pool_bytes = rep.exec.lease_pool_bytes;
        row.peak_leased_bytes = rep.exec.peak_leased_bytes;
        row.lease_waits = rep.exec.lease_waits;
        row.lease_denied_bytes = rep.exec.lease_denied_bytes;
        row.over_grant_bytes = rep.exec.over_grant_bytes;
        row.blocks_merged = rep.exec.blocks_merged;
        row.requests_per_sec = Some(rep.requests_per_sec);
        row.latency_p50_secs = Some(rep.p50_secs);
        row.latency_p99_secs = Some(rep.p99_secs);
        row.extra.push(("serve_sessions".to_string(), rep.sessions.to_string()));
        row.extra.push(("serve_max_batch".to_string(), rep.max_batch.to_string()));
        row.extra.push(("serve_requests".to_string(), rep.requests.to_string()));
        row.extra.push((
            "serve_mean_batch_rows".to_string(),
            format!("{:.2}", rep.mean_batch_rows),
        ));
        row
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("experiment".to_string(), Json::str(self.experiment.clone())),
            ("dataset".to_string(), Json::str(self.dataset.clone())),
            ("method".to_string(), Json::str(self.method.clone())),
            ("scheme".to_string(), Json::str(self.scheme.clone())),
            ("nt".to_string(), Json::num(self.nt as f64)),
            ("nfe_forward".to_string(), Json::num(self.nfe_forward as f64)),
            ("nfe_backward".to_string(), Json::num(self.nfe_backward as f64)),
            ("time_secs".to_string(), Json::num(self.time_secs)),
            ("model_mem_bytes".to_string(), Json::num(self.model_mem_bytes as f64)),
            (
                "measured_ckpt_bytes".to_string(),
                Json::num(self.measured_ckpt_bytes as f64),
            ),
            ("ckpt_hot_bytes".to_string(), Json::num(self.ckpt_hot_bytes as f64)),
            ("ckpt_cold_bytes".to_string(), Json::num(self.ckpt_cold_bytes as f64)),
            ("spill_count".to_string(), Json::num(self.spill_count as f64)),
            ("prefetch_hits".to_string(), Json::num(self.prefetch_hits as f64)),
            ("cold_reads".to_string(), Json::num(self.cold_reads as f64)),
            ("n_accepted".to_string(), Json::num(self.n_accepted as f64)),
            ("n_rejected".to_string(), Json::num(self.n_rejected as f64)),
            ("h_min".to_string(), Json::num(self.h_min)),
            ("h_max".to_string(), Json::num(self.h_max)),
            ("workers".to_string(), Json::num(self.workers as f64)),
            ("samples_per_sec".to_string(), Json::num(self.samples_per_sec)),
            ("lease_pool_bytes".to_string(), Json::num(self.lease_pool_bytes as f64)),
            ("peak_leased_bytes".to_string(), Json::num(self.peak_leased_bytes as f64)),
            ("lease_waits".to_string(), Json::num(self.lease_waits as f64)),
            ("lease_denied_bytes".to_string(), Json::num(self.lease_denied_bytes as f64)),
            ("over_grant_bytes".to_string(), Json::num(self.over_grant_bytes as f64)),
            ("blocks_merged".to_string(), Json::num(self.blocks_merged as f64)),
            (
                "mem_pred_ckpt_bytes".to_string(),
                Json::num(self.mem_pred_ckpt_bytes as f64),
            ),
            (
                "mem_obs_ckpt_bytes".to_string(),
                Json::num(self.mem_obs_ckpt_bytes as f64),
            ),
            ("mem_model_ratio".to_string(), Json::num(self.mem_model_ratio)),
            ("gemm_mul_adds".to_string(), Json::num(self.gemm_mul_adds as f64)),
        ];
        if let Some(v) = self.requests_per_sec {
            kv.push(("requests_per_sec".to_string(), Json::num(v)));
        }
        if let Some(v) = self.latency_p50_secs {
            kv.push(("latency_p50_secs".to_string(), Json::num(v)));
        }
        if let Some(v) = self.latency_p99_secs {
            kv.push(("latency_p99_secs".to_string(), Json::num(v)));
        }
        if let Some(p) = &self.policy_requested {
            kv.push(("policy_requested".to_string(), Json::str(p.clone())));
        }
        if let Some(p) = &self.policy_resolved {
            kv.push(("policy_resolved".to_string(), Json::str(p.clone())));
        }
        if !self.phase_secs.is_empty() {
            kv.push((
                "phase_secs".to_string(),
                Json::Obj(
                    self.phase_secs
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v)))
                        .collect(),
                ),
            ));
        }
        if let Some(spec) = &self.run_spec {
            kv.push(("run_spec".to_string(), spec.clone()));
        }
        for (k, v) in &self.extra {
            kv.push((k.clone(), Json::str(v.clone())));
        }
        Json::Obj(kv)
    }
}

/// One pure-Rust job body for the parallel matrix: builds its own state,
/// runs a gradient, returns the accounting.
pub type JobBody = Box<dyn FnOnce() -> MethodReport + Send>;

/// Identity of one job in a parallel matrix (see
/// [`Runner::run_jobs_parallel`]).
#[derive(Clone, Debug)]
pub struct JobMeta {
    pub dataset: String,
    pub method: String,
    pub scheme: String,
    pub nt: usize,
    pub model_mem_bytes: u64,
    /// serialized spec to embed in the row (facade-driven jobs)
    pub spec: Option<Json>,
}

impl JobMeta {
    /// Meta whose identity columns and embedded spec come from a
    /// [`RunSpec`].
    pub fn from_spec(dataset: &str, spec: &RunSpec, model_mem_bytes: u64) -> Self {
        JobMeta {
            dataset: dataset.into(),
            method: spec.method.name(),
            scheme: spec.scheme.name().into(),
            nt: spec.grid.planned_nt().unwrap_or(0),
            model_mem_bytes,
            spec: Some(spec.to_json()),
        }
    }
}

/// Collects rows, times jobs, writes JSON.
pub struct Runner {
    pub experiment: String,
    pub rows: Vec<ExperimentRow>,
    started: Instant,
}

impl Runner {
    pub fn new(experiment: &str) -> Self {
        Runner { experiment: experiment.into(), rows: Vec::new(), started: Instant::now() }
    }

    /// Time a job and push its row.
    #[allow(clippy::too_many_arguments)]
    pub fn run_job(
        &mut self,
        dataset: &str,
        method: &str,
        scheme: &str,
        nt: usize,
        model_mem_bytes: u64,
        job: impl FnOnce() -> MethodReport,
    ) -> &ExperimentRow {
        let t = Instant::now();
        let report = job();
        let secs = t.elapsed().as_secs_f64();
        self.rows.push(ExperimentRow::from_report(
            &self.experiment,
            dataset,
            method,
            scheme,
            nt,
            &report,
            secs,
            model_mem_bytes,
        ));
        // lint:allow(panic): last() on the row pushed one line above
        self.rows.last().unwrap()
    }

    /// Time a facade-driven job and push its row with the [`RunSpec`]
    /// embedded, so every result row carries the spec that produced it.
    pub fn run_spec_job(
        &mut self,
        dataset: &str,
        spec: &RunSpec,
        model_mem_bytes: u64,
        job: impl FnOnce() -> MethodReport,
    ) -> &ExperimentRow {
        let t = Instant::now();
        let report = job();
        let secs = t.elapsed().as_secs_f64();
        self.rows.push(ExperimentRow::from_spec_report(
            &self.experiment,
            dataset,
            spec,
            &report,
            secs,
            model_mem_bytes,
        ));
        // lint:allow(panic): last() on the row pushed one line above
        self.rows.last().unwrap()
    }

    /// Run a batch of independent pure-Rust jobs concurrently on the
    /// execution engine's worker pool and collect one row per job, in
    /// submission order (the pool's result slots are index-addressed, so
    /// the output is deterministic regardless of completion order).
    ///
    /// Each job is timed individually; under concurrency these times
    /// measure *occupancy*, not isolated latency — use [`Runner::run_job`]
    /// for precise per-job timing.
    pub fn run_jobs_parallel(
        &mut self,
        workers: usize,
        jobs: Vec<(JobMeta, JobBody)>,
    ) -> &[ExperimentRow] {
        let first = self.rows.len();
        let (metas, bodies): (Vec<JobMeta>, Vec<_>) = jobs.into_iter().unzip();
        let outs = pool::run_once_jobs(
            workers,
            bodies
                .into_iter()
                .map(|body| {
                    move || {
                        let t = Instant::now();
                        let report = body();
                        (report, t.elapsed().as_secs_f64())
                    }
                })
                .collect(),
        );
        for (meta, (report, secs)) in metas.into_iter().zip(outs) {
            let mut row = ExperimentRow::from_report(
                &self.experiment,
                &meta.dataset,
                &meta.method,
                &meta.scheme,
                meta.nt,
                &report,
                secs,
                meta.model_mem_bytes,
            );
            row.run_spec = meta.spec;
            self.rows.push(row);
        }
        &self.rows[first..]
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Persist all rows to `target/bench_results/<experiment>.json`.
    pub fn save(&self) -> std::io::Result<PathBuf> {
        let dir = PathBuf::from("target/bench_results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{}.json", self.experiment));
        let json = Json::Arr(self.rows.iter().map(|r| r.to_json()).collect());
        std::fs::write(&path, json.to_string_pretty())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_collects_and_serializes() {
        let mut r = Runner::new("unit_test");
        r.run_job("ds", "pnode", "rk4", 10, 123, || {
            let mut rep = MethodReport {
                nfe_forward: 40,
                nfe_backward: 40,
                ..Default::default()
            };
            rep.note_grid(&[(0.0, 0.25), (0.25, 0.75)], 3);
            rep
        });
        assert_eq!(r.rows.len(), 1);
        assert_eq!(r.rows[0].nfe_forward, 40);
        assert_eq!(r.rows[0].n_accepted, 2);
        assert_eq!(r.rows[0].n_rejected, 3);
        assert_eq!(r.rows[0].h_min, 0.25);
        assert_eq!(r.rows[0].h_max, 0.75);
        let j = r.rows[0].to_json().to_string_compact();
        assert!(j.contains("\"pnode\""));
        assert!(j.contains("\"nt\":10"));
        assert!(j.contains("\"spill_count\""), "tier columns serialized: {j}");
        assert!(j.contains("\"prefetch_hits\""));
        assert!(j.contains("\"ckpt_cold_bytes\""));
        assert!(j.contains("\"n_rejected\":3"), "grid columns serialized: {j}");
        assert!(j.contains("\"h_max\":0.75"));
        assert!(j.contains("\"workers\""), "exec columns serialized: {j}");
        assert!(j.contains("\"samples_per_sec\""));
        assert!(j.contains("\"peak_leased_bytes\""));
        assert!(j.contains("\"lease_waits\""));
    }

    #[test]
    fn attach_obs_fills_phase_and_memcheck_columns() {
        use crate::obs::{Event, EventKind, Metrics};
        let ev = |name: &'static str, kind: EventKind, seq: u64, ts: u64| Event {
            name,
            kind,
            tid: 0,
            seq,
            ts_nanos: ts,
            detail: None,
        };
        let events = vec![
            ev("forward", EventKind::Begin, 0, 0),
            ev("store", EventKind::Begin, 1, 100),
            ev("ckpt.hot_bytes", EventKind::Gauge(4096.0), 2, 150),
            ev("store", EventKind::End, 3, 200),
            ev("forward", EventKind::End, 4, 1_000),
            ev("gemm.mul_adds", EventKind::Counter(12288.0), 5, 1_100),
        ];
        let m = Metrics::from_events(&events);
        let mut row = ExperimentRow::from_report(
            "e",
            "d",
            "pnode",
            "rk4",
            4,
            &MethodReport::default(),
            0.0,
            0,
        );
        row.attach_obs(&m, 8192);
        assert_eq!(row.mem_obs_ckpt_bytes, 4096);
        assert_eq!(row.mem_pred_ckpt_bytes, 8192);
        assert!((row.mem_model_ratio - 0.5).abs() < 1e-12);
        let names: Vec<&str> = row.phase_secs.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["forward", "store"], "only phases that ran appear");
        let j = row.to_json().to_string_compact();
        assert!(j.contains("\"phase_secs\""), "{j}");
        assert!(j.contains("\"mem_model_ratio\":0.5"), "{j}");
        assert!(j.contains("\"blocks_merged\""), "{j}");
        assert!(j.contains("\"kernel\""), "kernel provenance column present: {j}");
        assert_eq!(row.gemm_mul_adds, 12288);
        assert!(j.contains("\"gemm_mul_adds\":12288"), "numeric column: {j}");
        assert!(
            !j.contains("policy_requested"),
            "concrete-policy rows omit the auto columns: {j}"
        );
    }

    #[test]
    fn auto_resolution_lands_in_policy_columns() {
        use crate::methods::AutoNote;
        let rep = MethodReport {
            auto: AutoNote::for_resolution(
                8 << 20,
                &crate::checkpoint::CheckpointPolicy::Binomial { n_checkpoints: 4 },
            ),
            ..Default::default()
        };
        let row = ExperimentRow::from_report("e", "d", "pnode:auto:8m", "rk4", 12, &rep, 0.0, 0);
        assert_eq!(row.policy_requested.as_deref(), Some("auto:8m"));
        assert_eq!(row.policy_resolved.as_deref(), Some("binomial:4"));
        let j = row.to_json().to_string_compact();
        assert!(j.contains("\"policy_requested\":\"auto:8m\""), "{j}");
        assert!(j.contains("\"policy_resolved\":\"binomial:4\""), "{j}");
    }

    #[test]
    fn spec_jobs_embed_the_run_spec_losslessly() {
        use crate::api::SolverBuilder;
        let spec = SolverBuilder::new()
            .method_str("pnode:binomial:3")
            .scheme_str("dopri5")
            .uniform(10)
            .build()
            .unwrap();
        let mut r = Runner::new("unit_spec");
        let row = r.run_spec_job("ds", &spec, 0, MethodReport::default);
        assert_eq!(row.method, "pnode:binomial:3");
        assert_eq!(row.scheme, "dopri5");
        assert_eq!(row.nt, 10);
        let embedded = row.run_spec.as_ref().expect("spec embedded");
        let back = crate::api::RunSpec::from_json(embedded).unwrap();
        assert_eq!(back, spec, "the row's spec re-parses to the producing spec");
        let j = row.to_json().to_string_compact();
        assert!(j.contains("\"run_spec\""), "{j}");
    }

    #[test]
    fn serve_rows_carry_throughput_and_latency_columns() {
        use crate::api::SolverBuilder;
        use crate::exec::ExecStats;
        use crate::serve::ServeReport;
        let spec = SolverBuilder::new().uniform(8).build().unwrap();
        let rep = ServeReport {
            requests: 640,
            batches: 40,
            sessions: 2,
            max_batch: 16,
            requests_per_sec: 1280.0,
            p50_secs: 1.5e-3,
            p99_secs: 4.0e-3,
            mean_batch_rows: 16.0,
            forward_allocs: 2,
            exec: ExecStats { workers: 2, samples_per_sec: 1300.0, ..Default::default() },
        };
        let row = ExperimentRow::from_serve_report("serve_bench", "clf_d64", &spec, &rep, 0.5);
        assert_eq!(row.requests_per_sec, Some(1280.0));
        assert_eq!(row.latency_p99_secs, Some(4.0e-3));
        assert_eq!(row.workers, 2);
        let j = row.to_json().to_string_compact();
        assert!(j.contains("\"requests_per_sec\":1280"), "{j}");
        assert!(j.contains("\"latency_p50_secs\""), "{j}");
        assert!(j.contains("\"latency_p99_secs\""), "{j}");
        assert!(j.contains("\"serve_sessions\":\"2\""), "{j}");
        // gradient rows omit the serve columns entirely
        let grad = ExperimentRow::from_report("e", "d", "pnode", "rk4", 4, &MethodReport::default(), 0.0, 0);
        let j = grad.to_json().to_string_compact();
        assert!(!j.contains("requests_per_sec"), "{j}");
    }

    #[test]
    fn parallel_job_matrix_keeps_submission_order() {
        let mut r = Runner::new("unit_par");
        let jobs: Vec<(JobMeta, JobBody)> = (0..9)
            .map(|i| {
                let meta = JobMeta {
                    dataset: format!("ds{i}"),
                    method: "pnode".into(),
                    scheme: "rk4".into(),
                    nt: i,
                    model_mem_bytes: 0,
                    spec: None,
                };
                let body: JobBody = Box::new(move || {
                    // uneven job durations scramble completion order
                    std::thread::sleep(std::time::Duration::from_millis(((9 - i) % 4) as u64));
                    MethodReport { nfe_forward: i as u64, ..Default::default() }
                });
                (meta, body)
            })
            .collect();
        let rows = r.run_jobs_parallel(4, jobs);
        assert_eq!(rows.len(), 9);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.dataset, format!("ds{i}"), "row order is submission order");
            assert_eq!(row.nfe_forward, i as u64);
            assert_eq!(row.nt, i);
        }
    }
}
