//! Continuous adjoint — the vanilla neural ODE's gradient (Chen et al.),
//! the paper's non-reverse-accurate baseline ("NODE cont").
//!
//! The augmented system [u, λ, μ] is integrated *backward* in time with the
//! same scheme and step count as the forward pass:
//!
//!   du/dt = f(u, θ, t)
//!   dλ/dt = −(∂f/∂u)ᵀ λ
//!   dμ/dt = −(∂f/∂θ)ᵀ λ
//!
//! u is reconstructed by reversing the trajectory (no storage — the O(N_l)
//! memory claim), which is exactly the source of the gradient inaccuracy:
//! the Jacobians are evaluated at backward-reconstructed states u ≠ u_n
//! (paper Table 1 / Prop. 1 bound the discrepancy by O(h²) per step).

use crate::ode::erk::integrate_fixed;
use crate::ode::rhs::{Nfe, OdeRhs};
use crate::ode::tableau::Tableau;

/// RHS of the backward augmented system, wrapping the model RHS.
struct AugmentedBackward<'a> {
    inner: &'a dyn OdeRhs,
    n: usize,
    p: usize,
}

impl<'a> OdeRhs for AugmentedBackward<'a> {
    fn state_len(&self) -> usize {
        2 * self.n + self.p
    }

    fn param_len(&self) -> usize {
        0
    }

    fn params(&self) -> &[f32] {
        &[]
    }

    fn set_params(&mut self, _theta: &[f32]) {}

    fn f(&self, t: f64, z: &[f32], out: &mut [f32]) {
        let (n, p) = (self.n, self.p);
        let (u, rest) = z.split_at(n);
        let (lam, _mu) = rest.split_at(n);
        let (out_u, out_rest) = out.split_at_mut(n);
        let (out_lam, out_mu) = out_rest.split_at_mut(n);
        // du/dt = f
        self.inner.f(t, u, out_u);
        // dλ/dt = -(∂f/∂u)ᵀλ ; dμ/dt = -(∂f/∂θ)ᵀλ
        let mut gtheta = vec![0.0f32; p];
        self.inner.vjp_both(t, u, lam, out_lam, &mut gtheta);
        for x in out_lam.iter_mut() {
            *x = -*x;
        }
        for (o, g) in out_mu.iter_mut().zip(&gtheta) {
            *o = -g;
        }
    }

    fn vjp_u(&self, _t: f64, _u: &[f32], _v: &[f32], _out: &mut [f32]) {
        unimplemented!("no second-order adjoints")
    }

    fn vjp_both(&self, _t: f64, _u: &[f32], _v: &[f32], _o: &mut [f32], _g: &mut [f32]) {
        unimplemented!("no second-order adjoints")
    }

    fn jvp(&self, _t: f64, _u: &[f32], _w: &[f32], _out: &mut [f32]) {
        unimplemented!("no second-order adjoints")
    }

    fn nfe(&self) -> Nfe {
        self.inner.nfe()
    }

    fn reset_nfe(&self) {
        self.inner.reset_nfe()
    }
}

/// Continuous-adjoint gradient for a fixed-step ERK forward pass.
///
/// `u_final` is the state at `tf` (from the forward integration), `lambda`
/// enters as ∂L/∂u(t_F) and leaves as ∂L/∂u_0; `grad_theta` accumulates
/// ∂L/∂θ.  The backward pass takes `nt` steps of the same scheme.
#[allow(clippy::too_many_arguments)]
pub fn continuous_adjoint_erk(
    tab: &Tableau,
    rhs: &dyn OdeRhs,
    t0: f64,
    tf: f64,
    nt: usize,
    u_final: &[f32],
    lambda: &mut [f32],
    grad_theta: &mut [f32],
) {
    let n = u_final.len();
    let p = rhs.param_len();
    let aug = AugmentedBackward { inner: rhs, n, p };
    let mut z0 = vec![0.0f32; 2 * n + p];
    z0[..n].copy_from_slice(u_final);
    z0[n..2 * n].copy_from_slice(lambda);
    // μ starts at 0
    let zf = integrate_fixed(tab, &aug, tf, t0, nt, &z0, |_, _, _, _, _, _| {});
    lambda.copy_from_slice(&zf[n..2 * n]);
    for (g, m) in grad_theta.iter_mut().zip(&zf[2 * n..]) {
        *g += m;
    }
}

/// Continuous-adjoint gradient over an explicit (possibly nonuniform)
/// forward step list: the augmented system retraces the recorded
/// `(t_n, h_n)` grid in reverse (each forward step `(t, h)` becomes the
/// backward step `(t + h, -h)`), so adaptive and nonuniform forward
/// passes get the matching backward discretization.
pub fn continuous_adjoint_erk_grid(
    tab: &Tableau,
    rhs: &dyn OdeRhs,
    steps: &[(f64, f64)],
    u_final: &[f32],
    lambda: &mut [f32],
    grad_theta: &mut [f32],
) {
    let n = u_final.len();
    let p = rhs.param_len();
    let aug = AugmentedBackward { inner: rhs, n, p };
    let mut z0 = vec![0.0f32; 2 * n + p];
    z0[..n].copy_from_slice(u_final);
    z0[n..2 * n].copy_from_slice(lambda);
    let reversed: Vec<(f64, f64)> =
        steps.iter().rev().map(|&(t, h)| (t + h, -h)).collect();
    let zf = crate::ode::erk::integrate_grid(tab, &aug, &reversed, &z0, |_, _, _, _, _, _| {});
    lambda.copy_from_slice(&zf[n..2 * n]);
    for (g, m) in grad_theta.iter_mut().zip(&zf[2 * n..]) {
        *g += m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Act;
    use crate::ode::erk::integrate_fixed;
    use crate::ode::ModuleRhs;
    use crate::ode::rhs::LinearRhs;
    use crate::ode::tableau;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    #[test]
    fn linear_problem_gradient_is_nearly_exact() {
        // For linear f the Hessian is zero => continuous == discrete adjoint
        // (paper Prop. 1), so the gradient must match finite differences.
        let d = 3;
        let mut rng = Rng::new(7);
        let mut a = prop::vec_normal(&mut rng, d * d);
        for x in a.iter_mut() {
            *x *= 0.3;
        }
        let rhs = LinearRhs::new(d, a);
        let u0 = prop::vec_normal(&mut rng, d);
        let w = prop::vec_normal(&mut rng, d);
        let tab = &tableau::RK4;
        let nt = 20;

        let uf = integrate_fixed(tab, &rhs, 0.0, 1.0, nt, &u0, |_, _, _, _, _, _| {});
        let mut lambda = w.clone();
        let mut gtheta = vec![0.0f32; d * d];
        continuous_adjoint_erk(tab, &rhs, 0.0, 1.0, nt, &uf, &mut lambda, &mut gtheta);

        let loss = |u0: &[f32]| {
            let uf = integrate_fixed(tab, &rhs, 0.0, 1.0, nt, u0, |_, _, _, _, _, _| {});
            crate::tensor::dot(&w, &uf)
        };
        let h = 1e-3f32;
        for idx in 0..d {
            let mut up = u0.clone();
            up[idx] += h;
            let mut um = u0.clone();
            um[idx] -= h;
            let fd = (loss(&up) - loss(&um)) / (2.0 * h as f64);
            assert!(
                (fd - lambda[idx] as f64).abs() < 5e-3 * (1.0 + fd.abs()),
                "dL/du[{idx}]: {} vs fd {fd}",
                lambda[idx]
            );
        }
    }

    #[test]
    fn grid_variant_matches_fixed_on_uniform_grids() {
        let dims = vec![3, 8, 3];
        let mut rng = Rng::new(21);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
        let rhs = ModuleRhs::mlp(dims, Act::Tanh, false, 1, theta);
        let u0 = vec![0.3f32, -0.2, 0.5];
        let w = vec![1.0f32, 0.5, -0.25];
        let tab = &tableau::RK4;
        let nt = 12;
        let uf = integrate_fixed(tab, &rhs, 0.0, 1.0, nt, &u0, |_, _, _, _, _, _| {});

        let mut l_fixed = w.clone();
        let mut g_fixed = vec![0.0f32; rhs.param_len()];
        continuous_adjoint_erk(tab, &rhs, 0.0, 1.0, nt, &uf, &mut l_fixed, &mut g_fixed);

        let steps = crate::ode::grid::uniform_steps(0.0, 1.0, nt);
        let mut l_grid = w.clone();
        let mut g_grid = vec![0.0f32; rhs.param_len()];
        continuous_adjoint_erk_grid(tab, &rhs, &steps, &uf, &mut l_grid, &mut g_grid);

        crate::testing::assert_allclose(&l_grid, &l_fixed, 1e-5, 1e-6, "grid λ");
        crate::testing::assert_allclose(&g_grid, &g_fixed, 1e-5, 1e-6, "grid θ̄");
    }

    #[test]
    fn nonlinear_gradient_has_order_h2_discrepancy() {
        // Prop. 1: per-step discrepancy O(h²) -> accumulated O(h).  Halving h
        // should roughly halve the gap between continuous and FD gradients.
        let dims = vec![2, 6, 2];
        let mut rng = Rng::new(11);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.5);
        let rhs = ModuleRhs::mlp(dims, Act::Tanh, false, 1, theta);
        let u0 = vec![0.4f32, -0.3];
        let w = vec![1.0f32, 0.5];
        let tab = &tableau::EULER;

        let gap = |nt: usize| -> f64 {
            let uf = integrate_fixed(tab, &rhs, 0.0, 1.0, nt, &u0, |_, _, _, _, _, _| {});
            let mut lambda = w.clone();
            let mut gtheta = vec![0.0f32; rhs.param_len()];
            continuous_adjoint_erk(tab, &rhs, 0.0, 1.0, nt, &uf, &mut lambda, &mut gtheta);
            // FD oracle for dL/du0
            let loss = |u0: &[f32]| {
                let uf = integrate_fixed(tab, &rhs, 0.0, 1.0, nt, u0, |_, _, _, _, _, _| {});
                crate::tensor::dot(&w, &uf)
            };
            let h = 1e-3f32;
            let mut worst = 0.0f64;
            for idx in 0..2 {
                let mut up = u0.clone();
                up[idx] += h;
                let mut um = u0.clone();
                um[idx] -= h;
                let fd = (loss(&up) - loss(&um)) / (2.0 * h as f64);
                worst = worst.max((fd - lambda[idx] as f64).abs());
            }
            worst
        };

        let g1 = gap(10);
        let g2 = gap(40);
        assert!(
            g2 < g1 * 0.6,
            "discrepancy should shrink with h: nt=10 gap {g1:.2e}, nt=40 gap {g2:.2e}"
        );
        assert!(g1 > 1e-6, "gap should be visible for coarse steps: {g1:.2e}");
    }
}
