//! Step schemes for the unified adjoint driver.
//!
//! A [`StepScheme`] packages everything the policy-aware driver needs to
//! run one time-stepping family forward and in reverse over an arbitrary
//! [`crate::ode::grid::TimeGrid`]:
//!
//! * [`ErkStep`] — explicit Runge–Kutta over a Butcher tableau.  Steps
//!   record stage derivatives; the adjoint of a step consumes `(u_n, ks)`
//!   and never reads the arrival state.
//! * [`ThetaStep`] — implicit θ-methods (backward Euler, Crank–Nicolson)
//!   via Newton–GMRES.  Steps record nothing beyond the solution; the
//!   adjoint of a step consumes `(u_n, u_{n+1})` and solves the transposed
//!   linearized step operator.
//!
//! Contract: when [`StepScheme::needs_stages`] is true, `adjoint_step`
//! must not read `u_next` (the driver may pass an empty slice when the
//! arrival state is not cheaply available); when it is false, `ks` is
//! always empty and `u_next` carries the arrival state.

use crate::adjoint::discrete_erk::{adjoint_erk_step, AdjointErkWorkspace};
use crate::adjoint::discrete_implicit::adjoint_theta_step;
use crate::linalg::gmres::GmresOptions;
use crate::obs;
use crate::ode::adaptive::{integrate_adaptive, AdaptiveController, AdaptiveResult};
use crate::ode::erk::{erk_step, integrate_grid, ErkWorkspace};
use crate::ode::implicit::{ImplicitStepper, ThetaScheme};
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Tableau;

/// Per-accepted-step sink: `(step, t, h, u_n, ks, u_{n+1})`.
pub type StepSink<'a> = &'a mut dyn FnMut(usize, f64, f64, &[f32], &[Vec<f32>], &[f32]);

/// A time-stepping family the adjoint driver can run forward and reverse.
pub trait StepScheme {
    /// Reusable forward-step workspace.
    type Fwd;
    /// Reusable adjoint-step workspace.
    type Adj;

    fn name(&self) -> &'static str;

    /// Stage vectors recorded per step (0 for schemes whose adjoint needs
    /// no stages).
    fn n_stages(&self) -> usize;

    /// Whether the adjoint of a step consumes recorded stage derivatives
    /// (true for ERK) as opposed to the arrival state (implicit θ).
    fn needs_stages(&self) -> bool {
        self.n_stages() > 0
    }

    fn fwd_workspace(&self, n: usize) -> Self::Fwd;

    fn adj_workspace(&self, n: usize) -> Self::Adj;

    /// Execute one forward step from `(t, h, u)`, filling `ks` (must hold
    /// `n_stages()` vectors) and `u_next`.
    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        rhs: &dyn OdeRhs,
        t: f64,
        h: f64,
        u: &[f32],
        ks: &mut [Vec<f32>],
        u_next: &mut [f32],
        ws: &mut Self::Fwd,
    );

    /// Reverse one step: `lambda` enters as λ_{n+1}, leaves as λ_n;
    /// `grad_theta` accumulates θ̄.  See the module docs for the
    /// `ks`/`u_next` contract.
    #[allow(clippy::too_many_arguments)]
    fn adjoint_step(
        &self,
        rhs: &dyn OdeRhs,
        t: f64,
        h: f64,
        u: &[f32],
        ks: &[Vec<f32>],
        u_next: &[f32],
        lambda: &mut [f32],
        grad_theta: &mut [f32],
        ws: &mut Self::Adj,
    );

    /// Drive a whole contiguous step list (FSAL-aware where applicable).
    /// Returns the final state.
    fn integrate(
        &self,
        rhs: &dyn OdeRhs,
        steps: &[(f64, f64)],
        u0: &[f32],
        sink: StepSink,
    ) -> Vec<f32>;

    /// PI-controlled adaptive pass generating the grid as it goes; `sink`
    /// fires on accepted steps only.  `None` if the scheme has no embedded
    /// error estimate.
    #[allow(clippy::too_many_arguments)]
    fn integrate_adaptive(
        &self,
        rhs: &dyn OdeRhs,
        t0: f64,
        tf: f64,
        atol: f64,
        rtol: f64,
        h0: f64,
        u0: &[f32],
        sink: StepSink,
    ) -> Option<AdaptiveResult>;
}

/// Explicit Runge–Kutta stepping over a Butcher tableau.
#[derive(Clone, Copy, Debug)]
pub struct ErkStep<'t> {
    pub tab: &'t Tableau,
}

impl StepScheme for ErkStep<'_> {
    type Fwd = ErkWorkspace;
    type Adj = AdjointErkWorkspace;

    fn name(&self) -> &'static str {
        self.tab.name
    }

    fn n_stages(&self) -> usize {
        self.tab.s
    }

    fn fwd_workspace(&self, n: usize) -> ErkWorkspace {
        ErkWorkspace::new(n)
    }

    fn adj_workspace(&self, n: usize) -> AdjointErkWorkspace {
        AdjointErkWorkspace::new(self.tab.s, n)
    }

    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        rhs: &dyn OdeRhs,
        t: f64,
        h: f64,
        u: &[f32],
        ks: &mut [Vec<f32>],
        u_next: &mut [f32],
        ws: &mut ErkWorkspace,
    ) {
        erk_step(self.tab, rhs, t, h, u, ks, u_next, ws, None);
    }

    #[allow(clippy::too_many_arguments)]
    fn adjoint_step(
        &self,
        rhs: &dyn OdeRhs,
        t: f64,
        h: f64,
        u: &[f32],
        ks: &[Vec<f32>],
        _u_next: &[f32],
        lambda: &mut [f32],
        grad_theta: &mut [f32],
        ws: &mut AdjointErkWorkspace,
    ) {
        adjoint_erk_step(self.tab, rhs, t, h, u, ks, lambda, grad_theta, ws);
    }

    fn integrate(
        &self,
        rhs: &dyn OdeRhs,
        steps: &[(f64, f64)],
        u0: &[f32],
        sink: StepSink,
    ) -> Vec<f32> {
        integrate_grid(self.tab, rhs, steps, u0, sink)
    }

    #[allow(clippy::too_many_arguments)]
    fn integrate_adaptive(
        &self,
        rhs: &dyn OdeRhs,
        t0: f64,
        tf: f64,
        atol: f64,
        rtol: f64,
        h0: f64,
        u0: &[f32],
        sink: StepSink,
    ) -> Option<AdaptiveResult> {
        if self.tab.b_err.is_none() {
            return None;
        }
        let ctrl = AdaptiveController::for_tableau(self.tab, atol, rtol);
        Some(integrate_adaptive(self.tab, rhs, t0, tf, h0, &ctrl, u0, sink))
    }
}

/// Implicit θ-method stepping (backward Euler θ=1, Crank–Nicolson θ=½)
/// with Newton–GMRES forward steps and transposed-GMRES adjoint steps.
#[derive(Clone, Debug)]
pub struct ThetaStep {
    pub scheme: ThetaScheme,
    /// options for the transposed adjoint solves
    pub gmres_opts: GmresOptions,
}

impl ThetaStep {
    pub fn new(scheme: ThetaScheme) -> Self {
        ThetaStep { scheme, gmres_opts: GmresOptions::default() }
    }
}

impl StepScheme for ThetaStep {
    type Fwd = ImplicitStepper;
    type Adj = ();

    fn name(&self) -> &'static str {
        self.scheme.name
    }

    fn n_stages(&self) -> usize {
        0
    }

    fn fwd_workspace(&self, n: usize) -> ImplicitStepper {
        ImplicitStepper::new(self.scheme, n)
    }

    fn adj_workspace(&self, _n: usize) {}

    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        rhs: &dyn OdeRhs,
        t: f64,
        h: f64,
        u: &[f32],
        _ks: &mut [Vec<f32>],
        u_next: &mut [f32],
        ws: &mut ImplicitStepper,
    ) {
        let rec = ws.step(rhs, t, h, u, u_next);
        if obs::enabled() {
            obs::counter("newton.iters", rec.newton.iters as f64);
            obs::counter("newton.linear_iters", rec.newton.linear_iters as f64);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn adjoint_step(
        &self,
        rhs: &dyn OdeRhs,
        t: f64,
        h: f64,
        u: &[f32],
        _ks: &[Vec<f32>],
        u_next: &[f32],
        lambda: &mut [f32],
        grad_theta: &mut [f32],
        _ws: &mut (),
    ) {
        // A stalled transposed solve is diagnosed but not fatal: the
        // stiff task's λ-jump ranges tolerate occasional stalls by design
        // (the old driver only asserted on its direct backward path), and
        // the solve warm-starts from λ, so a stall leaves λ at the best
        // available iterate.
        let res = adjoint_theta_step(
            self.scheme,
            rhs,
            t,
            h,
            u,
            u_next,
            lambda,
            grad_theta,
            &self.gmres_opts,
        );
        if obs::enabled() {
            obs::counter("gmres.transposed_iters", res.iters as f64);
        }
        if !res.converged {
            // diagnosed through the obs event path (no stderr noise): the
            // warning lands in the trace with its solve coordinates
            obs::warn("warn.theta_stall", || {
                format!(
                    "transposed {} solve stalled at t = {t:.6e} (h = {h:.3e}, residual = {:.3e})",
                    self.scheme.name, res.residual
                )
            });
        }
    }

    fn integrate(
        &self,
        rhs: &dyn OdeRhs,
        steps: &[(f64, f64)],
        u0: &[f32],
        sink: StepSink,
    ) -> Vec<f32> {
        let n = u0.len();
        let mut stepper = ImplicitStepper::new(self.scheme, n);
        let mut u = u0.to_vec();
        let mut u_next = vec![0.0f32; n];
        for (step, &(t, h)) in steps.iter().enumerate() {
            let rec = stepper.step(rhs, t, h, &u, &mut u_next);
            if obs::enabled() {
                obs::counter("newton.iters", rec.newton.iters as f64);
                obs::counter("newton.linear_iters", rec.newton.linear_iters as f64);
            }
            sink(step, t, h, &u, &[], &u_next);
            std::mem::swap(&mut u, &mut u_next);
        }
        u
    }

    #[allow(clippy::too_many_arguments)]
    fn integrate_adaptive(
        &self,
        _rhs: &dyn OdeRhs,
        _t0: f64,
        _tf: f64,
        _atol: f64,
        _rtol: f64,
        _h0: f64,
        _u0: &[f32],
        _sink: StepSink,
    ) -> Option<AdaptiveResult> {
        None // θ-methods carry no embedded error estimate
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::grid::uniform_steps;
    use crate::ode::implicit::integrate_implicit_grid;
    use crate::ode::rhs::LinearRhs;
    use crate::ode::tableau;

    #[test]
    fn erk_scheme_integrate_matches_free_function() {
        let rhs = LinearRhs::new(2, vec![0.0, 1.0, -1.0, 0.0]);
        let scheme = ErkStep { tab: &tableau::RK4 };
        let steps = uniform_steps(0.0, 1.0, 8);
        let u0 = [1.0f32, 0.0];
        let a = scheme.integrate(&rhs, &steps, &u0, &mut |_, _, _, _, _, _| {});
        let b = integrate_grid(&tableau::RK4, &rhs, &steps, &u0, |_, _, _, _, _, _| {});
        assert_eq!(a, b);
        assert!(scheme.needs_stages() && scheme.n_stages() == 4);
    }

    #[test]
    fn theta_scheme_integrate_matches_implicit_grid() {
        let rhs = LinearRhs::new(1, vec![-2.0]);
        let scheme = ThetaStep::new(ThetaScheme::crank_nicolson());
        let ts: Vec<f64> = vec![0.0, 0.2, 0.5, 1.0];
        let steps: Vec<(f64, f64)> = ts.windows(2).map(|w| (w[0], w[1] - w[0])).collect();
        let u0 = [1.0f32];
        let mut seen = 0usize;
        let a = scheme.integrate(&rhs, &steps, &u0, &mut |_, _, _, _, ks, _| {
            assert!(ks.is_empty(), "implicit steps record no stages");
            seen += 1;
        });
        let b = integrate_implicit_grid(
            ThetaScheme::crank_nicolson(),
            &rhs,
            &ts,
            &u0,
            |_, _, _, _, _| {},
        );
        assert_eq!(a, b);
        assert_eq!(seen, steps.len());
        assert!(!scheme.needs_stages());
        assert!(scheme
            .integrate_adaptive(&rhs, 0.0, 1.0, 1e-6, 1e-6, 0.1, &u0, &mut |_, _, _, _, _, _| {})
            .is_none());
    }
}
