//! Adjoint engines: discrete adjoints of the explicit RK family and of the
//! implicit theta-methods (reverse-accurate to machine precision), the
//! continuous-adjoint baseline (the vanilla neural ODE's gradient), the
//! step-scheme abstraction, and the checkpoint-policy-aware,
//! time-grid-generic backward driver.

pub mod continuous;
pub mod discrete_erk;
pub mod discrete_implicit;
pub mod driver;
pub mod scheme;

pub use continuous::{continuous_adjoint_erk, continuous_adjoint_erk_grid};
pub use discrete_erk::{adjoint_erk_step, AdjointErkWorkspace};
pub use discrete_implicit::adjoint_theta_step;
pub use driver::{AdjointDriver, ErkDriver, ThetaDriver};
pub use scheme::{ErkStep, StepScheme, ThetaStep};
