//! The checkpoint-policy-aware, time-grid-generic adjoint driver
//! (PNODE Algorithm 1).
//!
//! One driver, [`AdjointDriver<S: StepScheme>`], runs every gradient
//! configuration in the framework:
//!
//! * **Scheme** — [`ErkStep`] (explicit RK, stage-recording) or
//!   [`ThetaStep`] (implicit θ-methods, solution-recording); see
//!   [`crate::adjoint::scheme`].
//! * **Grid** — a [`TimeGrid`]: uniform, explicit nonuniform, or
//!   *adaptive*, where the forward pass generates the grid with the PI
//!   controller and records only the **accepted** `(t_n, h_n)` steps
//!   (rejected trials cost forward NFE but never enter the adjoint, the
//!   checkpoint store, or the backward NFE — paper §4).  The backward
//!   sweep then differentiates the accepted discrete map exactly.
//! * **Policy** — a [`CheckpointPolicy`]: `All` / `SolutionOnly` run a
//!   linear sweep; `Binomial` executes the DP-optimal Revolve-style
//!   schedule from [`crate::checkpoint::binomial`]; `Tiered` routes any
//!   placement through the RAM-budget/disk-spill backend.
//!
//! Storage is behind the [`CheckpointBackend`] trait: in-RAM by default,
//! or the tiered backend (RAM budget + disk spill + reverse-order
//! prefetch) when the policy is [`CheckpointPolicy::Tiered`].  Anchors
//! carry their own `(t_n, h_n)`, so the binomial DP, the tiered store's
//! least-soon-needed eviction, and the reverse prefetcher all work
//! verbatim off the recorded grid.  The backward pass brackets its work
//! with `begin_reverse_sweep`/`finish` so tiered backends can overlap
//! disk reads with stage recomputation.
//!
//! Adaptive grids and the binomial policy compose as follows: the DP
//! schedule needs the step count up front, which a single adaptive pass
//! cannot know, so the forward pass records the accepted grid only (plus
//! the free `u_0` anchor) and the backward executor creates checkpoints
//! by replaying from `u_0` under the DP's recompute-mode (`fwd = false`)
//! costs.  Replayed walks reproduce the forward states bitwise (an FSAL
//! stage equals a fresh evaluation at the same `(t, u)`), so gradients
//! are identical across placements and storage backends on the same
//! accepted grid.

use std::sync::Arc;

use crate::adjoint::scheme::{ErkStep, StepScheme, ThetaStep};
use crate::checkpoint::binomial::{Anchor, BinomialPlanner, BlockDecision};
use crate::checkpoint::tiered::{CheckpointBackend, TierStats, TieredConfig, TieredStore};
use crate::checkpoint::{CheckpointPolicy, CheckpointStore, MemoryBudget, StepCheckpoint};
use crate::exec::arbiter::BudgetArbiter;
use crate::obs;
use crate::ode::grid::{default_adaptive_h0, uniform_steps, TimeGrid};
use crate::ode::implicit::ThetaScheme;
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Tableau;

/// One full forward+backward gradient computation: scheme × grid × policy.
pub struct AdjointDriver<S> {
    pub scheme: S,
    pub policy: CheckpointPolicy,
    pub t0: f64,
    pub tf: f64,
    pub grid: TimeGrid,
    /// recorded (accepted) `(t_n, h_n)` steps of the latest forward pass
    steps: Vec<(f64, f64)>,
    /// rejected adaptive trials of the latest forward pass
    n_rejected: usize,
    store: Box<dyn CheckpointBackend>,
    /// `(u, ks)` of the final step, retained transiently from the forward
    /// pass (not kept for adaptive+binomial, whose backward replays from
    /// `u_0` anyway)
    transient_last: Option<(Vec<f32>, Vec<Vec<f32>>)>,
    /// number of re-executed forward steps during the backward pass
    pub recompute_steps: u64,
    planner: BinomialPlanner,
    final_state: Vec<f32>,
    /// whether the forward pass stored the binomial DP's checkpoints
    /// (false for adaptive grids and stage-free schemes: backward runs in
    /// the DP's recompute mode)
    fwd_stored: bool,
}

/// Explicit-RK driver (the `pnode*` methods).
pub type ErkDriver<'t> = AdjointDriver<ErkStep<'t>>;

/// Implicit θ-method driver (the stiff task).
pub type ThetaDriver = AdjointDriver<ThetaStep>;

impl<'t> ErkDriver<'t> {
    pub fn erk(
        tab: &'t Tableau,
        policy: CheckpointPolicy,
        t0: f64,
        tf: f64,
        grid: TimeGrid,
    ) -> Self {
        AdjointDriver::new(ErkStep { tab }, policy, t0, tf, grid)
    }

    /// Like [`ErkDriver::erk`], but a `Tiered` policy draws its hot-tier
    /// allowance from the shared `arbiter` pool (fleet mode) instead of
    /// owning the whole budget.  Crate-internal: fleets are configured
    /// through a parallel `crate::api::RunSpec`.
    pub(crate) fn erk_with_arbiter(
        tab: &'t Tableau,
        policy: CheckpointPolicy,
        t0: f64,
        tf: f64,
        grid: TimeGrid,
        arbiter: Option<Arc<BudgetArbiter>>,
    ) -> Self {
        AdjointDriver::new_with_arbiter(ErkStep { tab }, policy, t0, tf, grid, arbiter)
    }
}

impl ThetaDriver {
    /// Driver for an implicit θ-scheme over the time points `ts`
    /// (arbitrary, e.g. log-spaced).
    pub fn theta(scheme: ThetaScheme, policy: CheckpointPolicy, ts: &[f64]) -> Self {
        Self::theta_with_arbiter(scheme, policy, ts, None)
    }

    /// Like [`ThetaDriver::theta`], but a `Tiered` policy leases its
    /// hot-tier bytes from the shared `arbiter` pool (crate-internal
    /// fleet plumbing).
    pub(crate) fn theta_with_arbiter(
        scheme: ThetaScheme,
        policy: CheckpointPolicy,
        ts: &[f64],
        arbiter: Option<Arc<BudgetArbiter>>,
    ) -> Self {
        AdjointDriver::new_with_arbiter(
            ThetaStep::new(scheme),
            policy,
            ts[0],
            // lint:allow(panic): the driver is built from a validated BlockSpec whose grid has at least one node
            *ts.last().expect("nonempty time grid"),
            TimeGrid::from_times(ts),
            arbiter,
        )
    }
}

impl<S: StepScheme> AdjointDriver<S> {
    pub fn new(scheme: S, policy: CheckpointPolicy, t0: f64, tf: f64, grid: TimeGrid) -> Self {
        Self::new_with_arbiter(scheme, policy, t0, tf, grid, None)
    }

    /// Full constructor: a `Tiered` policy with `arbiter: Some(..)` joins
    /// the shared checkpoint-memory pool (its `budget_bytes` is the pool's
    /// display size; the actual allowance is leased per use).
    /// Crate-internal: fleets are configured through a parallel
    /// `crate::api::RunSpec`.
    pub(crate) fn new_with_arbiter(
        scheme: S,
        policy: CheckpointPolicy,
        t0: f64,
        tf: f64,
        grid: TimeGrid,
        arbiter: Option<Arc<BudgetArbiter>>,
    ) -> Self {
        let store: Box<dyn CheckpointBackend> = match &policy {
            CheckpointPolicy::Tiered { budget_bytes, dir, compress_f16, .. } => Box::new(
                TieredStore::create(TieredConfig {
                    budget: MemoryBudget::from_bytes(*budget_bytes),
                    dir: dir.into(),
                    compress_f16: *compress_f16,
                    prefetch_window: 4,
                    arbiter,
                })
                // lint:allow(panic): an unwritable spill dir is an unrecoverable environment fault at solver construction
                .expect("creating tiered checkpoint store (spill dir writable?)"),
            ),
            _ => Box::new(CheckpointStore::new()),
        };
        AdjointDriver {
            scheme,
            policy,
            t0,
            tf,
            grid,
            steps: Vec::new(),
            n_rejected: 0,
            store,
            transient_last: None,
            recompute_steps: 0,
            planner: BinomialPlanner::new(),
            final_state: Vec::new(),
            fwd_stored: true,
        }
    }

    // ---------------- forward ----------------

    /// Forward pass: integrates per the grid (generating it for
    /// [`TimeGrid::Adaptive`]), checkpoints per policy; returns `u(t_F)`.
    pub fn forward(&mut self, rhs: &dyn OdeRhs, u0: &[f32]) -> Vec<f32> {
        let _sp = obs::span("forward");
        self.store.clear();
        self.transient_last = None;
        self.recompute_steps = 0;
        self.n_rejected = 0;
        self.fwd_stored = true;
        match self.grid.clone() {
            TimeGrid::Uniform { nt } => {
                self.steps = uniform_steps(self.t0, self.tf, nt);
                self.forward_over_steps(rhs, u0)
            }
            TimeGrid::Explicit(steps) => {
                self.steps = steps;
                self.forward_over_steps(rhs, u0)
            }
            TimeGrid::Adaptive { atol, rtol, h0 } => {
                self.forward_adaptive(rhs, u0, atol, rtol, h0)
            }
        }
    }

    /// Pin the (free) bare anchor at step 0: the binomial executor always
    /// needs one, and `u_0` is the batch input.  `contains()` and not
    /// `get()`: a tiered get would pointlessly page the record in from
    /// disk just to test presence.
    fn pin_initial_anchor(&mut self, u0: &[f32]) {
        if !self.store.contains(0) {
            self.store.insert(StepCheckpoint {
                step: 0,
                t: self.t0,
                h: self.steps.first().map(|s| s.1).unwrap_or(0.0),
                u: u0.to_vec(),
                ks: None,
            });
        }
    }

    fn forward_over_steps(&mut self, rhs: &dyn OdeRhs, u0: &[f32]) -> Vec<f32> {
        let nt = self.steps.len();
        let is_binomial =
            matches!(self.policy.placement(), CheckpointPolicy::Binomial { .. });
        let store_positions: Vec<usize> = match self.policy.placement() {
            CheckpointPolicy::All | CheckpointPolicy::SolutionOnly => (0..nt).collect(),
            CheckpointPolicy::Binomial { n_checkpoints } => {
                if self.scheme.needs_stages() {
                    let nc = *n_checkpoints;
                    self.planner.forward_store_positions(nt, nc)
                } else {
                    // stage-free schemes gain nothing from forward-stored
                    // binomial checkpoints (there are no stages to keep):
                    // run the whole schedule in the DP's recompute mode
                    self.fwd_stored = false;
                    Vec::new()
                }
            }
            // lint:allow(panic): placement() lowers Tiered to its inner placement before this match
            CheckpointPolicy::Tiered { .. } => unreachable!("placement() is never Tiered"),
        };
        let with_stages = self.policy.stores_stages() && self.scheme.needs_stages();
        let scheme = &self.scheme;
        let steps = &self.steps;
        let store = &mut self.store;
        let transient = &mut self.transient_last;
        let uf = scheme.integrate(rhs, steps, u0, &mut |step, t, h, u, ks, _un| {
            if store_positions.binary_search(&step).is_ok() {
                let _sp = obs::span("store");
                store.insert(StepCheckpoint {
                    step,
                    t,
                    h,
                    u: u.to_vec(),
                    ks: with_stages.then(|| ks.to_vec()),
                });
                if obs::enabled() {
                    obs::gauge("ckpt.hot_bytes", store.stats().hot_bytes as f64);
                }
            }
            if step + 1 == nt {
                *transient = Some((u.to_vec(), ks.to_vec()));
            }
        });
        if is_binomial {
            self.pin_initial_anchor(u0);
        }
        self.final_state = uf.clone();
        uf
    }

    fn forward_adaptive(
        &mut self,
        rhs: &dyn OdeRhs,
        u0: &[f32],
        atol: f64,
        rtol: f64,
        h0: Option<f64>,
    ) -> Vec<f32> {
        let h0 = h0.unwrap_or_else(|| default_adaptive_h0(self.t0, self.tf));
        let is_binomial =
            matches!(self.policy.placement(), CheckpointPolicy::Binomial { .. });
        let with_stages = self.policy.stores_stages() && self.scheme.needs_stages();
        let res = if is_binomial {
            // grid-generation pass: record accepted steps only (see the
            // module docs); the backward executor replays from u_0
            self.fwd_stored = false;
            let scheme = &self.scheme;
            scheme.integrate_adaptive(
                rhs, self.t0, self.tf, atol, rtol, h0, u0,
                &mut |_, _, _, _, _, _| {},
            )
        } else {
            let scheme = &self.scheme;
            let store = &mut self.store;
            let transient = &mut self.transient_last;
            scheme.integrate_adaptive(
                rhs, self.t0, self.tf, atol, rtol, h0, u0,
                &mut |step, t, h, u, ks, _un| {
                    {
                        let _sp = obs::span("store");
                        store.insert(StepCheckpoint {
                            step,
                            t,
                            h,
                            u: u.to_vec(),
                            ks: with_stages.then(|| ks.to_vec()),
                        });
                        if obs::enabled() {
                            obs::gauge("ckpt.hot_bytes", store.stats().hot_bytes as f64);
                        }
                    }
                    // which step is last is unknown until the controller
                    // stops, so keep the latest (u, ks) as the transient —
                    // overwriting in place so the per-step cost is a copy,
                    // not an allocation.  This keeps backward NFE parity
                    // with a frozen-explicit replay of the accepted grid
                    // (SolutionOnly recomputes N_t − 1 on both).
                    match transient {
                        Some((tu, tks)) if tu.len() == u.len() && tks.len() == ks.len() => {
                            tu.copy_from_slice(u);
                            for (dst, src) in tks.iter_mut().zip(ks) {
                                dst.copy_from_slice(src);
                            }
                        }
                        _ => *transient = Some((u.to_vec(), ks.to_vec())),
                    }
                },
            )
        };
        let res = res.unwrap_or_else(|| {
            // lint:allow(panic): an adaptive grid on a scheme without an embedded estimate is a caller configuration bug, surfaced at first use
            panic!(
                "TimeGrid::Adaptive requires an embedded error estimate ({} has none)",
                self.scheme.name()
            )
        });
        self.steps = res.steps;
        self.n_rejected = res.rejected;
        if is_binomial {
            self.pin_initial_anchor(u0);
        }
        self.final_state = res.final_state.clone();
        res.final_state
    }

    // ---------------- observability ----------------

    pub fn final_state(&self) -> &[f32] {
        &self.final_state
    }

    /// The recorded (accepted) `(t_n, h_n)` steps of the latest forward
    /// pass — for adaptive grids, the grid the PI controller generated.
    pub fn grid_steps(&self) -> &[(f64, f64)] {
        &self.steps
    }

    /// Accepted step count of the latest forward pass.
    pub fn n_accepted(&self) -> usize {
        self.steps.len()
    }

    /// Rejected adaptive trials of the latest forward pass (0 for static
    /// grids).
    pub fn n_rejected(&self) -> usize {
        self.n_rejected
    }

    /// State at grid index `i` (`0` = initial, `n_accepted()` = final).
    /// Promotes the record from the cold tier if it was spilled — hence
    /// `&mut`.  Linear placements only (binomial consumes its anchors).
    pub fn state(&mut self, i: usize) -> &[f32] {
        if i == self.steps.len() {
            &self.final_state
        } else {
            // lint:allow(panic): the placement schedule stored this step (checked by the keep test above)
            &self.store.get(i).expect("state stored").u
        }
    }

    /// Peak checkpoint bytes resident in RAM (for tiered storage the cold
    /// tier is excluded — that is the point; see
    /// [`AdjointDriver::tier_stats`]).
    pub fn peak_checkpoint_bytes(&self) -> u64 {
        self.store.peak_hot_bytes()
    }

    pub fn checkpoint_count(&self) -> usize {
        self.store.len()
    }

    /// Storage-tier counters (hot/cold bytes, spills, prefetch hits);
    /// zeros beyond the hot fields for the in-memory backend.
    pub fn tier_stats(&self) -> TierStats {
        self.store.stats()
    }

    // ---------------- backward ----------------

    /// Backward pass: `lambda` enters as ∂L/∂u(t_F), leaves as ∂L/∂u_0;
    /// `grad_theta` accumulates ∂L/∂θ.
    pub fn backward(&mut self, rhs: &dyn OdeRhs, lambda: &mut [f32], grad_theta: &mut [f32]) {
        let _sp = obs::span("backward");
        let nt = self.steps.len();
        if nt == 0 {
            return;
        }
        self.store.begin_reverse_sweep();
        match self.policy.placement().clone() {
            CheckpointPolicy::All | CheckpointPolicy::SolutionOnly => {
                self.linear_sweep(rhs, 0, nt, false, lambda, grad_theta);
            }
            CheckpointPolicy::Binomial { n_checkpoints } => {
                assert!(
                    self.store.contains(0),
                    "binomial backward needs an anchor at step 0"
                );
                let n = lambda.len();
                let mut aws = self.scheme.adj_workspace(n);
                let mut ews = self.scheme.fwd_workspace(n);
                let fwd = self.fwd_stored;
                self.binomial_block(
                    rhs, 0, nt, n_checkpoints, fwd, lambda, grad_theta, &mut aws, &mut ews,
                );
            }
            // lint:allow(panic): placement() lowers Tiered to its inner placement before this match
            CheckpointPolicy::Tiered { .. } => unreachable!("placement() is never Tiered"),
        }
        self.store.finish();
    }

    /// Backward over the sub-range of steps `[i, j)` (multi-observation
    /// losses add λ jumps between ranges — see tasks/stiff.rs).  Consumes
    /// the checkpoints in `(i, j]`; the checkpoint at `i` stays stored so
    /// the next (lower) range can reuse it.  Linear placements only.
    pub fn backward_range(
        &mut self,
        rhs: &dyn OdeRhs,
        i: usize,
        j: usize,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        assert!(
            matches!(
                self.policy.placement(),
                CheckpointPolicy::All | CheckpointPolicy::SolutionOnly
            ),
            "backward_range requires a linear (All/SolutionOnly) placement"
        );
        if i >= j {
            return;
        }
        self.store.begin_reverse_sweep();
        self.linear_sweep(rhs, i, j, true, lambda, grad_theta);
        self.store.finish();
    }

    /// Linear reverse sweep over steps `[i, j)`.  Carries the arrival
    /// state `u_{n+1}` down the sweep (stage-free schemes consume it;
    /// stage-recording schemes use stored or recomputed stages).
    fn linear_sweep(
        &mut self,
        rhs: &dyn OdeRhs,
        i: usize,
        j: usize,
        keep_boundary: bool,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        let n = lambda.len();
        let nt = self.steps.len();
        let mut fws = self.scheme.fwd_workspace(n);
        let mut aws = self.scheme.adj_workspace(n);
        let needs_stages = self.scheme.needs_stages();
        let mut ks_buf: Vec<Vec<f32>> =
            (0..self.scheme.n_stages()).map(|_| vec![0.0f32; n]).collect();
        let mut un_buf = vec![0.0f32; n];
        let mut upper: Vec<f32> = if j == nt {
            self.final_state.clone()
        } else {
            // lint:allow(panic): range boundaries are always kept by the placement schedule
            self.store.take(j).expect("range boundary state stored").u
        };
        for step in (i..j).rev() {
            let (t, h) = self.steps[step];
            let keep = keep_boundary && step == i;
            // the global last step's (u, ks) may be retained transiently
            // from the forward pass: adjoint it without a recompute
            if step + 1 == nt && !keep && self.transient_last.is_some() {
                // lint:allow(panic): guarded by the transient_last.is_some() arm of the enclosing condition
                let (u, tks) = self.transient_last.take().expect("transient last step");
                let _ = self.store.take(step); // consume the slot if stored
                let _sp = obs::span("vjp");
                self.scheme
                    .adjoint_step(rhs, t, h, &u, &tks, &upper, lambda, grad_theta, &mut aws);
                upper = u;
                continue;
            }
            let cp = {
                let _sp = obs::span("restore");
                if keep {
                    // lint:allow(panic): the keep test just confirmed the placement schedule stored this step
                    self.store.get(step).expect("state stored").clone()
                } else {
                    // lint:allow(panic): the recompute loop stored this step into the transient slot above
                    self.store.take(step).expect("state stored")
                }
            };
            if needs_stages {
                if let Some(ks) = cp.ks.as_ref() {
                    let _sp = obs::span("vjp");
                    self.scheme
                        .adjoint_step(rhs, t, h, &cp.u, ks, &upper, lambda, grad_theta, &mut aws);
                } else {
                    // recompute this step's stages (one step execution)
                    {
                        let _sp = obs::span("recompute");
                        self.scheme.step(rhs, t, h, &cp.u, &mut ks_buf, &mut un_buf, &mut fws);
                    }
                    self.recompute_steps += 1;
                    let _sp = obs::span("vjp");
                    self.scheme.adjoint_step(
                        rhs, t, h, &cp.u, &ks_buf, &upper, lambda, grad_theta, &mut aws,
                    );
                }
            } else {
                let _sp = obs::span("vjp");
                self.scheme
                    .adjoint_step(rhs, t, h, &cp.u, &[], &upper, lambda, grad_theta, &mut aws);
            }
            upper = cp.u;
        }
    }

    /// Recursive executor for the binomial policy, mirroring the DP.
    #[allow(clippy::too_many_arguments)]
    fn binomial_block(
        &mut self,
        rhs: &dyn OdeRhs,
        lo: usize,
        hi: usize,
        c: usize,
        fwd: bool,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
        aws: &mut S::Adj,
        ews: &mut S::Fwd,
    ) {
        if lo >= hi {
            return;
        }
        let n = lambda.len();
        let nt = self.steps.len();
        let len = hi - lo;
        let needs_stages = self.scheme.needs_stages();
        // For stage-free schemes a bare solution anchor is as good as a
        // full one (the adjoint re-executes the step either way), so
        // report Full to the planner — a Split{offset: 0} upgrade would
        // otherwise loop forever.
        let anchor_kind = if !needs_stages
            || self.store.get(lo).map(|cp| cp.ks.is_some()).unwrap_or(false)
        {
            Anchor::Full
        } else {
            Anchor::Bare
        };

        if len == 1 {
            // adjoint step `lo`
            let (t, h) = self.steps[lo];
            if lo + 1 == nt && self.transient_last.is_some() {
                // lint:allow(panic): guarded by the transient_last.is_some() arm of the enclosing condition
                let (u, tks) = self.transient_last.take().expect("transient last step");
                let u_next = self.final_state.clone();
                let _sp = obs::span("vjp");
                self.scheme
                    .adjoint_step(rhs, t, h, &u, &tks, &u_next, lambda, grad_theta, aws);
            } else {
                let cp = {
                    let _sp = obs::span("restore");
                    self.store
                        .get(lo)
                        // lint:allow(panic): the binomial schedule places an anchor at every range it revisits
                        .unwrap_or_else(|| panic!("binomial executor: no anchor at step {lo}"))
                        .clone()
                };
                match (needs_stages, cp.ks.as_ref()) {
                    (true, Some(ks)) => {
                        let _sp = obs::span("vjp");
                        self.scheme
                            .adjoint_step(rhs, t, h, &cp.u, ks, &[], lambda, grad_theta, aws);
                    }
                    _ => {
                        // re-execute the step for its stages / arrival
                        // state.  (Known slack for stage-free schemes: the
                        // arrival state equals the anchor of the
                        // previously-adjointed step, which the executor
                        // does not thread through — the DP's Anchor::Full
                        // cost model undercounts this one execution.
                        // Binomial placement on θ-schemes is a secondary
                        // combination; the linear sweep carries the state
                        // and pays zero recomputes.)
                        let mut ks: Vec<Vec<f32>> =
                            (0..self.scheme.n_stages()).map(|_| vec![0.0f32; n]).collect();
                        let mut un = vec![0.0f32; n];
                        {
                            let _sp = obs::span("recompute");
                            self.scheme.step(rhs, t, h, &cp.u, &mut ks, &mut un, ews);
                        }
                        self.recompute_steps += 1;
                        let _sp = obs::span("vjp");
                        self.scheme
                            .adjoint_step(rhs, t, h, &cp.u, &ks, &un, lambda, grad_theta, aws);
                    }
                }
            }
            let _ = self.store.take(lo);
            return;
        }

        match self.planner.decide(len, c, anchor_kind, fwd) {
            BlockDecision::DirectLast => {
                // adjoint step hi-1 via walk from the anchor, then recurse
                let last = hi - 1;
                let (tl, hl) = self.steps[last];
                if last + 1 == nt && self.transient_last.is_some() {
                    // lint:allow(panic): guarded by the transient_last.is_some() arm of the enclosing condition
                    let (u, tks) = self.transient_last.take().expect("transient last step");
                    let u_next = self.final_state.clone();
                    let _sp = obs::span("vjp");
                    self.scheme
                        .adjoint_step(rhs, tl, hl, &u, &tks, &u_next, lambda, grad_theta, aws);
                } else {
                    let mut u = {
                        let _sp = obs::span("restore");
                        // lint:allow(panic): the binomial schedule places an anchor at every range it revisits
                        self.store.get(lo).expect("anchor checkpoint").u.clone()
                    };
                    let mut un = vec![0.0f32; n];
                    let mut ks: Vec<Vec<f32>> =
                        (0..self.scheme.n_stages()).map(|_| vec![0.0f32; n]).collect();
                    {
                        let _sp = obs::span("recompute");
                        for s in lo..last {
                            let (t, h) = self.steps[s];
                            self.scheme.step(rhs, t, h, &u, &mut ks, &mut un, ews);
                            self.recompute_steps += 1;
                            std::mem::swap(&mut u, &mut un);
                        }
                        // one more execution for step `last` itself
                        self.scheme.step(rhs, tl, hl, &u, &mut ks, &mut un, ews);
                        self.recompute_steps += 1;
                    }
                    let _sp = obs::span("vjp");
                    self.scheme
                        .adjoint_step(rhs, tl, hl, &u, &ks, &un, lambda, grad_theta, aws);
                }
                self.binomial_block(rhs, lo, hi - 1, c, false, lambda, grad_theta, aws, ews);
            }
            BlockDecision::Split { offset } => {
                if offset == 0 {
                    // upgrade the bare anchor at `lo` to full (only ever
                    // decided for stage-recording schemes)
                    if anchor_kind == Anchor::Bare && !fwd {
                        let cp = {
                            let _sp = obs::span("restore");
                            // lint:allow(panic): the binomial schedule places an anchor at every range it revisits
                            self.store.get(lo).expect("anchor").clone()
                        };
                        let (t, h) = self.steps[lo];
                        let mut ks: Vec<Vec<f32>> =
                            (0..self.scheme.n_stages()).map(|_| vec![0.0f32; n]).collect();
                        let mut un = vec![0.0f32; n];
                        {
                            let _sp = obs::span("recompute");
                            self.scheme.step(rhs, t, h, &cp.u, &mut ks, &mut un, ews);
                        }
                        self.recompute_steps += 1;
                        let _sp = obs::span("store");
                        self.store.insert(StepCheckpoint { ks: Some(ks), ..cp });
                    }
                    // fwd case: the forward pass already stored it full
                    self.binomial_block(rhs, lo, hi, c - 1, fwd, lambda, grad_theta, aws, ews);
                    return;
                }
                let mid = lo + offset;
                if !fwd && self.store.get(mid).is_none() {
                    // create the checkpoint by walking from the anchor
                    let mut u = {
                        let _sp = obs::span("restore");
                        // lint:allow(panic): the binomial schedule places an anchor at every range it revisits
                        self.store.get(lo).expect("anchor checkpoint").u.clone()
                    };
                    let mut un = vec![0.0f32; n];
                    let mut ks: Vec<Vec<f32>> =
                        (0..self.scheme.n_stages()).map(|_| vec![0.0f32; n]).collect();
                    let (tm, hm) = self.steps[mid];
                    let stored_ks = {
                        let _sp = obs::span("recompute");
                        for s in lo..mid {
                            let (t, h) = self.steps[s];
                            self.scheme.step(rhs, t, h, &u, &mut ks, &mut un, ews);
                            self.recompute_steps += 1;
                            std::mem::swap(&mut u, &mut un);
                        }
                        if needs_stages {
                            // one extra execution for the stages of step `mid`
                            self.scheme.step(rhs, tm, hm, &u, &mut ks, &mut un, ews);
                            self.recompute_steps += 1;
                            Some(ks)
                        } else {
                            None
                        }
                    };
                    let _sp = obs::span("store");
                    self.store
                        .insert(StepCheckpoint { step: mid, t: tm, h: hm, u, ks: stored_ks });
                    if obs::enabled() {
                        obs::gauge("ckpt.hot_bytes", self.store.stats().hot_bytes as f64);
                    }
                }
                // right block first (backward order), then left
                self.binomial_block(rhs, mid, hi, c - 1, fwd, lambda, grad_theta, aws, ews);
                self.binomial_block(rhs, lo, mid, c, false, lambda, grad_theta, aws, ews);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Act;
    use crate::ode::ModuleRhs;
    use crate::ode::tableau;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn mk_rhs(seed: u64) -> ModuleRhs {
        let dims = vec![4, 7, 3];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.2);
        ModuleRhs::mlp(dims, Act::Tanh, true, 2, theta)
    }

    /// gradient of L = <w, u(tF)> via an ERK run with the given policy
    fn grad_with_policy(
        policy: CheckpointPolicy,
        rhs: &ModuleRhs,
        u0: &[f32],
        w: &[f32],
        nt: usize,
    ) -> (Vec<f32>, Vec<f32>, u64) {
        let mut run =
            ErkDriver::erk(&tableau::RK4, policy, 0.0, 1.0, TimeGrid::Uniform { nt });
        run.forward(rhs, u0);
        let mut lambda = w.to_vec();
        let mut gtheta = vec![0.0f32; rhs.param_len()];
        run.backward(rhs, &mut lambda, &mut gtheta);
        (lambda, gtheta, run.recompute_steps)
    }

    #[test]
    fn policies_give_identical_gradients() {
        let rhs = mk_rhs(31);
        let n = rhs.state_len();
        let mut rng = Rng::new(32);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 12;

        let (l_all, g_all, r_all) = grad_with_policy(CheckpointPolicy::All, &rhs, &u0, &w, nt);
        let (l_sol, g_sol, r_sol) =
            grad_with_policy(CheckpointPolicy::SolutionOnly, &rhs, &u0, &w, nt);
        let (l_bin, g_bin, r_bin) = grad_with_policy(
            CheckpointPolicy::Binomial { n_checkpoints: 3 },
            &rhs,
            &u0,
            &w,
            nt,
        );

        assert_eq!(r_all, 0, "All policy recomputes nothing");
        assert_eq!(r_sol, (nt - 1) as u64, "SolutionOnly recomputes N_t - 1");
        assert!(r_bin > 0, "binomial with few slots must recompute");
        crate::testing::assert_allclose(&l_sol, &l_all, 1e-5, 1e-6, "λ sol vs all");
        crate::testing::assert_allclose(&g_sol, &g_all, 1e-5, 1e-6, "θ̄ sol vs all");
        crate::testing::assert_allclose(&l_bin, &l_all, 1e-5, 1e-6, "λ bin vs all");
        crate::testing::assert_allclose(&g_bin, &g_all, 1e-5, 1e-6, "θ̄ bin vs all");
    }

    #[test]
    fn binomial_recompute_matches_dp_prediction() {
        let rhs = mk_rhs(41);
        let n = rhs.state_len();
        let mut rng = Rng::new(42);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        for (nt, nc) in [(8usize, 2usize), (12, 3), (16, 2), (20, 5)] {
            let (_, _, recomputes) = grad_with_policy(
                CheckpointPolicy::Binomial { n_checkpoints: nc },
                &rhs,
                &u0,
                &w,
                nt,
            );
            let predicted = crate::checkpoint::binomial::optimal_extra_steps(nt, nc);
            assert_eq!(
                recomputes, predicted,
                "nt={nt} nc={nc}: executed {recomputes} != DP {predicted}"
            );
        }
    }

    #[test]
    fn explicit_grid_reproduces_uniform_bitwise() {
        let rhs = mk_rhs(111);
        let n = rhs.state_len();
        let mut rng = Rng::new(112);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 10;

        let grad = |grid: TimeGrid| {
            let mut run =
                ErkDriver::erk(&tableau::DOPRI5, CheckpointPolicy::All, 0.0, 1.0, grid);
            run.forward(&rhs, &u0);
            let mut l = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            run.backward(&rhs, &mut l, &mut g);
            (l, g, run.grid_steps().to_vec())
        };
        let (l_u, g_u, steps) = grad(TimeGrid::Uniform { nt });
        let (l_e, g_e, steps_e) = grad(TimeGrid::Explicit(steps.clone()));
        assert_eq!(steps, steps_e);
        assert_eq!(l_u, l_e, "explicit copy of the uniform grid is the same map");
        assert_eq!(g_u, g_e);
    }

    #[test]
    fn nonuniform_explicit_grid_gradients_agree_across_policies() {
        let rhs = mk_rhs(121);
        let n = rhs.state_len();
        let mut rng = Rng::new(122);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let steps =
            vec![(0.0, 0.05), (0.05, 0.1), (0.15, 0.2), (0.35, 0.3), (0.65, 0.35)];

        let grad = |policy: CheckpointPolicy| {
            let mut run = ErkDriver::erk(
                &tableau::RK4, policy, 0.0, 1.0, TimeGrid::Explicit(steps.clone()),
            );
            run.forward(&rhs, &u0);
            let mut l = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            run.backward(&rhs, &mut l, &mut g);
            (l, g)
        };
        let (l_all, g_all) = grad(CheckpointPolicy::All);
        for policy in [
            CheckpointPolicy::SolutionOnly,
            CheckpointPolicy::Binomial { n_checkpoints: 2 },
        ] {
            let (l, g) = grad(policy.clone());
            assert_eq!(l, l_all, "{}: λ bitwise on a nonuniform grid", policy.name());
            assert_eq!(g, g_all, "{}: θ̄ bitwise on a nonuniform grid", policy.name());
        }
    }

    #[test]
    fn adaptive_grid_policies_and_tiers_bitwise_identical() {
        let rhs = mk_rhs(101);
        let n = rhs.state_len();
        let mut rng = Rng::new(102);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let grid = TimeGrid::Adaptive { atol: 1e-5, rtol: 1e-5, h0: Some(0.25) };

        let grad = |policy: CheckpointPolicy| {
            let mut run = ErkDriver::erk(&tableau::DOPRI5, policy, 0.0, 1.0, grid.clone());
            run.forward(&rhs, &u0);
            let mut l = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            run.backward(&rhs, &mut l, &mut g);
            let st = run.tier_stats();
            (l, g, run.n_accepted(), run.n_rejected(), st, run.recompute_steps)
        };
        let (l_all, g_all, acc, rej, _, r_all) = grad(CheckpointPolicy::All);
        assert!(acc > 1, "controller must accept multiple steps");
        assert_eq!(r_all, 0, "All placement never recomputes");
        let (l_bin, g_bin, acc_b, rej_b, _, r_bin) =
            grad(CheckpointPolicy::Binomial { n_checkpoints: 3 });
        assert_eq!((acc, rej), (acc_b, rej_b), "deterministic accepted grid");
        assert!(r_bin > 0, "recompute-mode schedule must replay steps");
        assert_eq!(l_bin, l_all, "binomial λ bitwise on the same accepted grid");
        assert_eq!(g_bin, g_all, "binomial θ̄ bitwise on the same accepted grid");

        let dir = tmp_spill_dir("adaptive");
        let policy = CheckpointPolicy::Tiered {
            budget_bytes: 300,
            dir: dir.clone(),
            compress_f16: false,
            inner: Box::new(CheckpointPolicy::Binomial { n_checkpoints: 3 }),
        };
        let (l_t, g_t, acc_t, _, st, _) = grad(policy);
        assert_eq!(acc_t, acc);
        assert_eq!(l_t, l_all, "tiered binomial λ bitwise under adaptive stepping");
        assert_eq!(g_t, g_all, "tiered binomial θ̄ bitwise under adaptive stepping");
        assert!(st.spills > 0, "300 B budget must force spills: {st:?}");
        let _ = std::fs::remove_dir_all(std::path::Path::new(&dir));
    }

    #[test]
    fn adaptive_rejections_never_touch_the_store_or_backward() {
        // a moderately stiff problem with a generous trial step forces
        // rejected trials; they must cost forward NFE only (paper §4)
        use crate::ode::rhs::LinearRhs;
        let rhs = LinearRhs::new(2, vec![-40.0, 0.0, 0.0, -1.0]);
        let u0 = vec![1.0f32, 1.0];
        let w = vec![1.0f32, 1.0];
        for policy in [CheckpointPolicy::All, CheckpointPolicy::SolutionOnly] {
            let grad = |grid: TimeGrid| {
                rhs.reset_nfe();
                let mut run =
                    ErkDriver::erk(&tableau::DOPRI5, policy.clone(), 0.0, 1.0, grid);
                run.forward(&rhs, &u0);
                let fwd_nfe = rhs.nfe().forward;
                let mut l = w.clone();
                let mut g = vec![0.0f32; rhs.param_len()];
                run.backward(&rhs, &mut l, &mut g);
                let bwd = rhs.nfe();
                (
                    run.grid_steps().to_vec(),
                    run.n_rejected(),
                    fwd_nfe,
                    bwd.backward + (bwd.forward - fwd_nfe),
                    run.peak_checkpoint_bytes(),
                    run.recompute_steps,
                    l,
                    g,
                )
            };
            let ada = TimeGrid::Adaptive { atol: 1e-6, rtol: 1e-6, h0: Some(0.5) };
            let (steps, rejected, nfe_f_ada, nfe_b_ada, bytes_ada, rec_ada, l_a, g_a) =
                grad(ada);
            assert!(rejected > 0, "h0=0.5 on a stiff axis must reject trials");
            // replay the frozen accepted grid: same adjoint, same memory,
            // same recompute schedule, strictly fewer forward evaluations
            let (steps2, rej2, nfe_f_ex, nfe_b_ex, bytes_ex, rec_ex, l_e, g_e) =
                grad(TimeGrid::Explicit(steps.clone()));
            let tag = policy.name();
            assert_eq!(steps, steps2);
            assert_eq!(rej2, 0);
            assert_eq!(nfe_b_ada, nfe_b_ex, "{tag}: rejections add zero backward NFE");
            assert_eq!(bytes_ada, bytes_ex, "{tag}: rejections add zero checkpoint bytes");
            assert_eq!(rec_ada, rec_ex, "{tag}: rejections never enter the schedule");
            assert!(
                nfe_f_ada > nfe_f_ex,
                "{tag}: rejected trials must cost forward NFE: {nfe_f_ada} vs {nfe_f_ex}"
            );
            assert_eq!(l_a, l_e, "{tag}: gradients differentiate the accepted map only");
            assert_eq!(g_a, g_e, "{tag}");
        }
    }

    fn tmp_spill_dir(tag: &str) -> String {
        let d = std::env::temp_dir()
            .join(format!("pnode-driver-tiered-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d.to_string_lossy().into_owned()
    }

    #[test]
    fn tiered_spill_gradients_are_bitwise_identical_to_in_memory() {
        let rhs = mk_rhs(71);
        let n = rhs.state_len();
        let mut rng = Rng::new(72);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 16;

        let (l_mem, g_mem, _) = grad_with_policy(CheckpointPolicy::All, &rhs, &u0, &w, nt);

        let dir = tmp_spill_dir("all");
        // budget far below one full trajectory: forces spilling
        let policy = CheckpointPolicy::Tiered {
            budget_bytes: 600,
            dir: dir.clone(),
            compress_f16: false,
            inner: Box::new(CheckpointPolicy::All),
        };
        let mut run =
            ErkDriver::erk(&tableau::RK4, policy, 0.0, 1.0, TimeGrid::Uniform { nt });
        run.forward(&rhs, &u0);
        let mut l_t = w.to_vec();
        let mut g_t = vec![0.0f32; rhs.param_len()];
        run.backward(&rhs, &mut l_t, &mut g_t);
        let st = run.tier_stats();

        assert_eq!(run.recompute_steps, 0, "All placement never recomputes");
        assert_eq!(l_t, l_mem, "λ bitwise identical across backends");
        assert_eq!(g_t, g_mem, "θ̄ bitwise identical across backends");
        assert!(st.spills > 0, "budget must force spills: {st:?}");
        assert!(st.prefetch_hits > 0, "reverse sweep must use the prefetcher: {st:?}");
        assert!(st.cold_bytes_written > 0);
        assert!(
            st.peak_hot_bytes <= 600 + 2 * 500,
            "hot tier stays near budget: {st:?}"
        );
        let _ = std::fs::remove_dir_all(std::path::Path::new(&dir));
    }

    #[test]
    fn tiered_composes_with_binomial_and_solution_only() {
        let rhs = mk_rhs(81);
        let n = rhs.state_len();
        let mut rng = Rng::new(82);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 12;
        let (l_ref, g_ref, _) = grad_with_policy(CheckpointPolicy::All, &rhs, &u0, &w, nt);

        for (tag, inner, want_recompute) in [
            ("bin", CheckpointPolicy::Binomial { n_checkpoints: 3 }, None),
            ("sol", CheckpointPolicy::SolutionOnly, Some((nt - 1) as u64)),
        ] {
            let dir = tmp_spill_dir(tag);
            let policy = CheckpointPolicy::Tiered {
                budget_bytes: 512,
                dir: dir.clone(),
                compress_f16: false,
                inner: Box::new(inner.clone()),
            };
            let (l, g, recompute) = grad_with_policy(policy, &rhs, &u0, &w, nt);
            assert_eq!(l, l_ref, "{tag}: λ bitwise vs in-memory All");
            assert_eq!(g, g_ref, "{tag}: θ̄ bitwise vs in-memory All");
            if let Some(want) = want_recompute {
                assert_eq!(recompute, want, "{tag}");
            }
            // recompute counts must match the same placement without tiers
            let (_, _, recompute_mem) = grad_with_policy(inner, &rhs, &u0, &w, nt);
            assert_eq!(recompute, recompute_mem, "{tag}: tiering never changes the schedule");
            let _ = std::fs::remove_dir_all(std::path::Path::new(&dir));
        }
    }

    #[test]
    fn tiered_f16_compression_accounts_error_and_stays_close() {
        let rhs = mk_rhs(91);
        let n = rhs.state_len();
        let mut rng = Rng::new(92);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 16;
        let (l_ref, g_ref, _) = grad_with_policy(CheckpointPolicy::All, &rhs, &u0, &w, nt);

        let dir = tmp_spill_dir("f16");
        let policy = CheckpointPolicy::Tiered {
            budget_bytes: 600,
            dir: dir.clone(),
            compress_f16: true,
            inner: Box::new(CheckpointPolicy::All),
        };
        let mut run =
            ErkDriver::erk(&tableau::RK4, policy, 0.0, 1.0, TimeGrid::Uniform { nt });
        run.forward(&rhs, &u0);
        let mut l = w.to_vec();
        let mut g = vec![0.0f32; rhs.param_len()];
        run.backward(&rhs, &mut l, &mut g);
        let st = run.tier_stats();
        assert!(st.compressed_elems > 0, "{st:?}");
        assert!(st.compress_max_abs_err > 0.0 && st.compress_max_abs_err < 5e-2, "{st:?}");
        // f16 state error (~5e-4 relative) propagates mildly into gradients
        crate::testing::assert_allclose(&l, &l_ref, 1e-1, 1e-3, "f16 λ");
        crate::testing::assert_allclose(&g, &g_ref, 1e-1, 1e-3, "f16 θ̄");
        let _ = std::fs::remove_dir_all(std::path::Path::new(&dir));
    }

    #[test]
    fn full_gradient_matches_finite_differences() {
        let mut rhs = mk_rhs(51);
        let n = rhs.state_len();
        let p = rhs.param_len();
        let mut rng = Rng::new(52);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 8;
        let (lambda, gtheta, _) =
            grad_with_policy(CheckpointPolicy::All, &rhs, &u0, &w, nt);

        let loss = |rhs: &dyn OdeRhs, u0: &[f32]| {
            let uf = crate::ode::erk::integrate_fixed(
                &tableau::RK4, rhs, 0.0, 1.0, nt, u0, |_, _, _, _, _, _| {},
            );
            crate::tensor::dot(&w, &uf)
        };
        let fd = 1e-3f32;
        for idx in 0..n.min(4) {
            let mut up = u0.clone();
            up[idx] += fd;
            let mut um = u0.clone();
            um[idx] -= fd;
            let d = (loss(&rhs, &up) - loss(&rhs, &um)) / (2.0 * fd as f64);
            assert!(
                (d - lambda[idx] as f64).abs() < 1e-2 * (1.0 + d.abs()),
                "dL/du[{idx}] {} vs fd {d}",
                lambda[idx]
            );
        }
        let theta0 = rhs.params().to_vec();
        for idx in [0usize, p / 2, p - 1] {
            let mut tp = theta0.clone();
            tp[idx] += fd;
            rhs.set_params(&tp);
            let lp = loss(&rhs, &u0);
            let mut tm = theta0.clone();
            tm[idx] -= fd;
            rhs.set_params(&tm);
            let lm = loss(&rhs, &u0);
            rhs.set_params(&theta0);
            let d = (lp - lm) / (2.0 * fd as f64);
            assert!(
                (d - gtheta[idx] as f64).abs() < 1e-2 * (1.0 + d.abs()),
                "dL/dθ[{idx}] {} vs fd {d}",
                gtheta[idx]
            );
        }
    }

    fn mk_implicit_rhs(seed: u64) -> ModuleRhs {
        let dims = vec![3, 8, 3];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
        ModuleRhs::mlp(dims, Act::Gelu, false, 1, theta)
    }

    #[test]
    fn implicit_tiered_matches_in_memory_bitwise() {
        let rhs = mk_implicit_rhs(63);
        let ts: Vec<f64> = (0..=12).map(|i| i as f64 / 12.0).collect();
        let u0 = vec![0.5f32, -0.2, 0.1];
        let w = vec![1.0f32, -0.5, 0.25];

        let grad = |run: &mut ThetaDriver| {
            run.forward(&rhs, &u0);
            let mut l = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            run.backward(&rhs, &mut l, &mut g);
            (l, g)
        };
        let mut mem = ThetaDriver::theta(
            ThetaScheme::crank_nicolson(),
            CheckpointPolicy::SolutionOnly,
            &ts,
        );
        let (l_mem, g_mem) = grad(&mut mem);

        let dir = tmp_spill_dir("implicit");
        // each state record is 3*4+48 = 60 B; 12 stored states ≈ 720 B
        let mut tr = ThetaDriver::theta(
            ThetaScheme::crank_nicolson(),
            CheckpointPolicy::Tiered {
                budget_bytes: 150,
                dir: dir.clone(),
                compress_f16: false,
                inner: Box::new(CheckpointPolicy::SolutionOnly),
            },
            &ts,
        );
        let (l_t, g_t) = grad(&mut tr);
        let st = tr.tier_stats();

        assert_eq!(l_t, l_mem, "implicit λ bitwise identical across backends");
        assert_eq!(g_t, g_mem, "implicit θ̄ bitwise identical across backends");
        assert!(st.spills > 0, "150 B budget must spill: {st:?}");
        assert!(st.prefetch_hits > 0, "backward sweep prefetches: {st:?}");
        let _ = std::fs::remove_dir_all(std::path::Path::new(&dir));
    }

    #[test]
    fn theta_binomial_schedule_matches_linear_sweep() {
        // binomial placement on a stage-free scheme runs in the DP's
        // recompute mode; replayed Newton walks are deterministic, so the
        // gradient is bitwise identical to the stored-trajectory sweep
        let rhs = mk_implicit_rhs(67);
        let ts = vec![0.0, 0.05, 0.15, 0.3, 0.55, 1.0];
        let u0 = vec![0.4f32, -0.1, 0.3];
        let w = vec![1.0f32, 0.5, -0.3];

        let grad = |policy: CheckpointPolicy| {
            let mut run =
                ThetaDriver::theta(ThetaScheme::crank_nicolson(), policy, &ts);
            run.forward(&rhs, &u0);
            let mut l = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            run.backward(&rhs, &mut l, &mut g);
            (l, g, run.recompute_steps, run.peak_checkpoint_bytes())
        };
        let (l_lin, g_lin, r_lin, bytes_lin) = grad(CheckpointPolicy::SolutionOnly);
        assert_eq!(r_lin, 0, "the carried-upper sweep never re-runs Newton");
        let (l_bin, g_bin, r_bin, bytes_bin) =
            grad(CheckpointPolicy::Binomial { n_checkpoints: 2 });
        assert!(r_bin > 0, "two slots over five steps must replay");
        assert!(bytes_bin < bytes_lin, "binomial stores less than the full trajectory");
        assert_eq!(l_bin, l_lin, "θ-scheme λ bitwise across schedules");
        assert_eq!(g_bin, g_lin, "θ-scheme θ̄ bitwise across schedules");
    }

    #[test]
    fn implicit_run_gradient_matches_fd() {
        use crate::ode::implicit::integrate_implicit_grid;
        let mut rhs = mk_implicit_rhs(61);
        let ts = vec![0.0, 0.1, 0.25, 0.5, 1.0];
        let u0 = vec![0.5f32, -0.2, 0.1];
        let w = vec![1.0f32, -0.5, 0.25];

        let mut run = ThetaDriver::theta(
            ThetaScheme::crank_nicolson(),
            CheckpointPolicy::SolutionOnly,
            &ts,
        );
        run.forward(&rhs, &u0);
        let mut lambda = w.clone();
        let mut gtheta = vec![0.0f32; rhs.param_len()];
        run.backward(&rhs, &mut lambda, &mut gtheta);

        let loss = |rhs: &dyn OdeRhs, u0: &[f32]| {
            let uf = integrate_implicit_grid(
                ThetaScheme::crank_nicolson(),
                rhs,
                &ts,
                u0,
                |_, _, _, _, _| {},
            );
            crate::tensor::dot(&w, &uf)
        };
        let fd = 1e-3f32;
        for idx in 0..3 {
            let mut up = u0.clone();
            up[idx] += fd;
            let mut um = u0.clone();
            um[idx] -= fd;
            let d = (loss(&rhs, &up) - loss(&rhs, &um)) / (2.0 * fd as f64);
            assert!(
                (d - lambda[idx] as f64).abs() < 2e-2 * (1.0 + d.abs()),
                "dL/du[{idx}] {} vs fd {d}",
                lambda[idx]
            );
        }
        let p = rhs.param_len();
        let theta0 = rhs.params().to_vec();
        for idx in [0usize, p - 1] {
            let mut tp = theta0.clone();
            tp[idx] += fd;
            rhs.set_params(&tp);
            let lp = loss(&rhs, &u0);
            let mut tm = theta0.clone();
            tm[idx] -= fd;
            rhs.set_params(&tm);
            let lm = loss(&rhs, &u0);
            rhs.set_params(&theta0);
            let d = (lp - lm) / (2.0 * fd as f64);
            assert!(
                (d - gtheta[idx] as f64).abs() < 2e-2 * (1.0 + d.abs()),
                "dL/dθ[{idx}] {} vs fd {d}",
                gtheta[idx]
            );
        }
    }
}
