//! The checkpoint-policy-aware adjoint driver (PNODE Algorithm 1).
//!
//! Forward: integrate, storing checkpoints per [`CheckpointPolicy`].
//! Backward: walk steps in reverse; restore the closest checkpoint and
//! recompute as dictated by the policy (for the binomial policy, the
//! DP-optimal schedule from [`crate::checkpoint::binomial`]).
//!
//! Storage is behind the [`CheckpointBackend`] trait: in-RAM by default,
//! or the tiered backend (RAM budget + disk spill + reverse-order
//! prefetch) when the policy is [`CheckpointPolicy::Tiered`].  The
//! backward pass brackets its work with `begin_reverse_sweep`/`finish` so
//! tiered backends can overlap disk reads with stage recomputation.

use crate::adjoint::discrete_erk::{adjoint_erk_step, AdjointErkWorkspace};
use crate::adjoint::discrete_implicit::adjoint_theta_step;
use crate::checkpoint::binomial::{Anchor, BinomialPlanner, BlockDecision};
use crate::checkpoint::tiered::{CheckpointBackend, TierStats, TieredConfig, TieredStore};
use crate::checkpoint::{CheckpointPolicy, CheckpointStore, MemoryBudget, StepCheckpoint};
use crate::linalg::gmres::GmresOptions;
use crate::ode::erk::{erk_step, integrate_fixed, ErkWorkspace};
use crate::ode::implicit::{integrate_implicit_grid, ThetaScheme};
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Tableau;

/// One full forward+backward gradient computation over an ERK scheme.
pub struct ErkAdjointRun<'t> {
    pub tab: &'t Tableau,
    pub policy: CheckpointPolicy,
    pub t0: f64,
    pub tf: f64,
    pub nt: usize,
    store: Box<dyn CheckpointBackend>,
    /// (u, ks) of the final step, retained transiently from the forward pass
    transient_last: Option<(Vec<f32>, Vec<Vec<f32>>)>,
    /// number of re-executed forward steps during the backward pass
    pub recompute_steps: u64,
    planner: BinomialPlanner,
    final_state: Vec<f32>,
}

impl<'t> ErkAdjointRun<'t> {
    pub fn new(tab: &'t Tableau, policy: CheckpointPolicy, t0: f64, tf: f64, nt: usize) -> Self {
        let store: Box<dyn CheckpointBackend> = match &policy {
            CheckpointPolicy::Tiered { budget_bytes, dir, compress_f16, .. } => Box::new(
                TieredStore::create(TieredConfig {
                    budget: MemoryBudget::from_bytes(*budget_bytes),
                    dir: dir.into(),
                    compress_f16: *compress_f16,
                    prefetch_window: 4,
                })
                .expect("creating tiered checkpoint store (spill dir writable?)"),
            ),
            _ => Box::new(CheckpointStore::new()),
        };
        ErkAdjointRun {
            tab,
            policy,
            t0,
            tf,
            nt,
            store,
            transient_last: None,
            recompute_steps: 0,
            planner: BinomialPlanner::new(),
            final_state: Vec::new(),
        }
    }

    fn h(&self) -> f64 {
        (self.tf - self.t0) / self.nt as f64
    }

    fn t_of(&self, step: usize) -> f64 {
        self.t0 + step as f64 * self.h()
    }

    /// Forward pass: integrates and checkpoints per policy; returns u(t_F).
    pub fn forward(&mut self, rhs: &dyn OdeRhs, u0: &[f32]) -> Vec<f32> {
        self.store.clear();
        self.transient_last = None;
        self.recompute_steps = 0;
        let h = self.h();
        let nt = self.nt;
        let store_positions: Vec<usize> = match self.policy.placement() {
            CheckpointPolicy::All | CheckpointPolicy::SolutionOnly => (0..nt).collect(),
            CheckpointPolicy::Binomial { n_checkpoints } => {
                let nc = *n_checkpoints;
                self.planner.forward_store_positions(nt, nc)
            }
            CheckpointPolicy::Tiered { .. } => unreachable!("placement() is never Tiered"),
        };
        let with_stages = self.policy.stores_stages();
        let store = &mut self.store;
        let transient = &mut self.transient_last;
        let uf = integrate_fixed(self.tab, rhs, self.t0, self.tf, nt, u0, |step, t, h_, u, ks, _un| {
            debug_assert!((h_ - h).abs() < 1e-12);
            if store_positions.binary_search(&step).is_ok() {
                store.insert(StepCheckpoint {
                    step,
                    t,
                    h,
                    u: u.to_vec(),
                    ks: with_stages.then(|| ks.to_vec()),
                });
            }
            if step == nt - 1 {
                *transient = Some((u.to_vec(), ks.to_vec()));
            }
        });
        // the binomial executor always needs an anchor at step 0; the input
        // u_0 is available for free (it is the batch), so pin it (bare).
        // contains() and not get(): a tiered get would pointlessly page the
        // record in from disk just to test presence.
        if matches!(self.policy.placement(), CheckpointPolicy::Binomial { .. })
            && !self.store.contains(0)
        {
            self.store.insert(StepCheckpoint {
                step: 0,
                t: self.t0,
                h,
                u: u0.to_vec(),
                ks: None,
            });
        }
        self.final_state = uf.clone();
        uf
    }

    pub fn final_state(&self) -> &[f32] {
        &self.final_state
    }

    /// Peak checkpoint bytes resident in RAM (for tiered storage the cold
    /// tier is excluded — that is the point; see [`ErkAdjointRun::tier_stats`]).
    pub fn peak_checkpoint_bytes(&self) -> u64 {
        self.store.peak_hot_bytes()
    }

    pub fn checkpoint_count(&self) -> usize {
        self.store.len()
    }

    /// Storage-tier counters (hot/cold bytes, spills, prefetch hits);
    /// zeros beyond the hot fields for the in-memory backend.
    pub fn tier_stats(&self) -> TierStats {
        self.store.stats()
    }

    /// Backward pass: `lambda` enters as ∂L/∂u(t_F), leaves as ∂L/∂u_0;
    /// `grad_theta` accumulates ∂L/∂θ.
    pub fn backward(&mut self, rhs: &dyn OdeRhs, lambda: &mut [f32], grad_theta: &mut [f32]) {
        let n = lambda.len();
        let mut aws = AdjointErkWorkspace::new(self.tab.s, n);
        let mut ews = ErkWorkspace::new(n);
        self.store.begin_reverse_sweep();
        match self.policy.placement().clone() {
            CheckpointPolicy::All => {
                for step in (0..self.nt).rev() {
                    let cp = self.store.take(step).expect("checkpoint stored");
                    let ks = cp.ks.as_ref().expect("stages stored");
                    adjoint_erk_step(
                        self.tab, rhs, cp.t, cp.h, &cp.u, ks, lambda, grad_theta, &mut aws,
                    );
                }
            }
            CheckpointPolicy::SolutionOnly => {
                let h = self.h();
                let mut ks: Vec<Vec<f32>> = (0..self.tab.s).map(|_| vec![0.0f32; n]).collect();
                let mut u_next = vec![0.0f32; n];
                for step in (0..self.nt).rev() {
                    let cp = self.store.take(step).expect("checkpoint stored");
                    if step == self.nt - 1 {
                        if let Some((u, tks)) = self.transient_last.take() {
                            adjoint_erk_step(
                                self.tab, rhs, cp.t, h, &u, &tks, lambda, grad_theta, &mut aws,
                            );
                            continue;
                        }
                    }
                    // recompute this step's stages (1 step execution)
                    erk_step(self.tab, rhs, cp.t, h, &cp.u, &mut ks, &mut u_next, &mut ews, None);
                    self.recompute_steps += 1;
                    adjoint_erk_step(
                        self.tab, rhs, cp.t, h, &cp.u, &ks, lambda, grad_theta, &mut aws,
                    );
                }
            }
            CheckpointPolicy::Binomial { n_checkpoints } => {
                assert!(
                    self.store.contains(0),
                    "binomial forward must checkpoint step 0 or caller's u0"
                );
                self.binomial_block(rhs, 0, self.nt, n_checkpoints, true, lambda, grad_theta, &mut aws, &mut ews);
            }
            CheckpointPolicy::Tiered { .. } => unreachable!("placement() is never Tiered"),
        }
        self.store.finish();
    }

    /// Recursive executor for the binomial policy, mirroring the DP.
    #[allow(clippy::too_many_arguments)]
    fn binomial_block(
        &mut self,
        rhs: &dyn OdeRhs,
        lo: usize,
        hi: usize,
        c: usize,
        fwd: bool,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
        aws: &mut AdjointErkWorkspace,
        ews: &mut ErkWorkspace,
    ) {
        if lo >= hi {
            return;
        }
        let n = lambda.len();
        let h = self.h();
        let len = hi - lo;
        let anchor_kind = if self.store.get(lo).map(|cp| cp.ks.is_some()).unwrap_or(false) {
            Anchor::Full
        } else {
            Anchor::Bare
        };

        if len == 1 {
            // adjoint step `lo`
            let (u, ks_owned);
            if fwd && lo == self.nt - 1 {
                let (tu, tks) = self.transient_last.take().expect("transient last stages");
                u = tu;
                ks_owned = tks;
            } else if let Some(cp) = self.store.get(lo) {
                if let Some(ks) = &cp.ks {
                    u = cp.u.clone();
                    ks_owned = ks.clone();
                } else {
                    let mut ks: Vec<Vec<f32>> = (0..self.tab.s).map(|_| vec![0.0f32; n]).collect();
                    let mut un = vec![0.0f32; n];
                    erk_step(self.tab, rhs, cp.t, h, &cp.u, &mut ks, &mut un, ews, None);
                    self.recompute_steps += 1;
                    u = cp.u.clone();
                    ks_owned = ks;
                }
            } else {
                panic!("binomial executor: no anchor at step {lo}");
            }
            adjoint_erk_step(self.tab, rhs, self.t_of(lo), h, &u, &ks_owned, lambda, grad_theta, aws);
            let _ = self.store.take(lo);
            return;
        }

        match self.planner.decide(len, c, anchor_kind, fwd) {
            BlockDecision::DirectLast => {
                // adjoint step hi-1 via walk from anchor at lo, then recurse
                let last = hi - 1;
                if fwd && last == self.nt - 1 {
                    let (u, ks) = self.transient_last.take().expect("transient last stages");
                    adjoint_erk_step(
                        self.tab, rhs, self.t_of(last), h, &u, &ks, lambda, grad_theta, aws,
                    );
                } else {
                    let anchor = self.store.get(lo).expect("anchor checkpoint").u.clone();
                    let mut u = anchor;
                    let mut un = vec![0.0f32; n];
                    let mut ks: Vec<Vec<f32>> = (0..self.tab.s).map(|_| vec![0.0f32; n]).collect();
                    for s in lo..last {
                        erk_step(self.tab, rhs, self.t_of(s), h, &u, &mut ks, &mut un, ews, None);
                        self.recompute_steps += 1;
                        std::mem::swap(&mut u, &mut un);
                    }
                    // one more execution for the stages of step `last`
                    erk_step(self.tab, rhs, self.t_of(last), h, &u, &mut ks, &mut un, ews, None);
                    self.recompute_steps += 1;
                    adjoint_erk_step(
                        self.tab, rhs, self.t_of(last), h, &u, &ks, lambda, grad_theta, aws,
                    );
                }
                self.binomial_block(rhs, lo, hi - 1, c, false, lambda, grad_theta, aws, ews);
            }
            BlockDecision::Split { offset } => {
                if offset == 0 {
                    // upgrade anchor at lo to full
                    if anchor_kind == Anchor::Bare && !fwd {
                        let cp = self.store.get(lo).expect("anchor").clone();
                        let mut ks: Vec<Vec<f32>> =
                            (0..self.tab.s).map(|_| vec![0.0f32; n]).collect();
                        let mut un = vec![0.0f32; n];
                        erk_step(self.tab, rhs, cp.t, h, &cp.u, &mut ks, &mut un, ews, None);
                        self.recompute_steps += 1;
                        self.store.insert(StepCheckpoint { ks: Some(ks), ..cp });
                    }
                    // fwd case: forward pass already stored it full
                    self.binomial_block(rhs, lo, hi, c - 1, fwd, lambda, grad_theta, aws, ews);
                    return;
                }
                let mid = lo + offset;
                if !fwd && self.store.get(mid).is_none() {
                    // create the checkpoint by walking (offset steps + 1 for stages)
                    let anchor = self.store.get(lo).expect("anchor checkpoint").u.clone();
                    let mut u = anchor;
                    let mut un = vec![0.0f32; n];
                    let mut ks: Vec<Vec<f32>> = (0..self.tab.s).map(|_| vec![0.0f32; n]).collect();
                    for s in lo..mid {
                        erk_step(self.tab, rhs, self.t_of(s), h, &u, &mut ks, &mut un, ews, None);
                        self.recompute_steps += 1;
                        std::mem::swap(&mut u, &mut un);
                    }
                    erk_step(self.tab, rhs, self.t_of(mid), h, &u, &mut ks, &mut un, ews, None);
                    self.recompute_steps += 1;
                    self.store.insert(StepCheckpoint {
                        step: mid,
                        t: self.t_of(mid),
                        h,
                        u,
                        ks: Some(ks),
                    });
                }
                // right block first (backward order), then left
                self.binomial_block(rhs, mid, hi, c - 1, fwd, lambda, grad_theta, aws, ews);
                self.binomial_block(rhs, lo, mid, c, false, lambda, grad_theta, aws, ews);
            }
        }
    }
}

/// Gradient run for the implicit theta-methods: solution-only checkpoints
/// over an arbitrary (possibly log-spaced) time grid, stored through the
/// same [`CheckpointBackend`] abstraction as the ERK run — so long stiff
/// trajectories can run under a RAM budget with disk spill + prefetch
/// ([`ImplicitAdjointRun::tiered`]).
pub struct ImplicitAdjointRun {
    pub scheme: ThetaScheme,
    pub ts: Vec<f64>,
    pub gmres_opts: GmresOptions,
    /// u_n at every grid index (solutions only — no stages for implicit)
    store: Box<dyn CheckpointBackend>,
}

impl ImplicitAdjointRun {
    pub fn new(scheme: ThetaScheme, ts: Vec<f64>) -> Self {
        Self::with_backend(scheme, ts, Box::new(CheckpointStore::new()))
    }

    /// Tiered storage: at most `cfg.budget` bytes of trajectory resident,
    /// the rest spilled under `cfg.dir` and prefetched back in reverse
    /// order during the backward sweep.
    pub fn tiered(
        scheme: ThetaScheme,
        ts: Vec<f64>,
        cfg: TieredConfig,
    ) -> std::io::Result<Self> {
        Ok(Self::with_backend(scheme, ts, Box::new(TieredStore::create(cfg)?)))
    }

    fn with_backend(scheme: ThetaScheme, ts: Vec<f64>, store: Box<dyn CheckpointBackend>) -> Self {
        ImplicitAdjointRun { scheme, ts, gmres_opts: GmresOptions::default(), store }
    }

    /// Forward integration storing every solution; returns u(t_F).
    pub fn forward(&mut self, rhs: &dyn OdeRhs, u0: &[f32]) -> Vec<f32> {
        self.store.clear();
        let ts = &self.ts;
        let step_h = |i: usize| if i + 1 < ts.len() { ts[i + 1] - ts[i] } else { 0.0 };
        self.store.insert(StepCheckpoint {
            step: 0,
            t: ts[0],
            h: step_h(0),
            u: u0.to_vec(),
            ks: None,
        });
        let store = &mut self.store;
        let mut idx = 0usize;
        integrate_implicit_grid(self.scheme, rhs, ts, u0, |_, _, _, _, un| {
            idx += 1;
            store.insert(StepCheckpoint {
                step: idx,
                t: ts[idx],
                h: step_h(idx),
                u: un.to_vec(),
                ks: None,
            });
        })
    }

    /// State at grid index i (0 = initial).  Promotes the record from the
    /// cold tier if it was spilled — hence `&mut`.
    pub fn state(&mut self, i: usize) -> &[f32] {
        &self.store.get(i).expect("state stored").u
    }

    /// Trajectory bytes currently resident in RAM.
    pub fn checkpoint_bytes(&self) -> u64 {
        self.store.hot_bytes()
    }

    /// Storage-tier counters (zeros beyond the hot fields in-memory).
    pub fn tier_stats(&self) -> TierStats {
        self.store.stats()
    }

    /// Backward sweep over all steps; λ and θ-gradient as in the ERK run.
    pub fn backward(&mut self, rhs: &dyn OdeRhs, lambda: &mut [f32], grad_theta: &mut [f32]) {
        self.backward_range_impl(rhs, 0, self.ts.len() - 1, lambda, grad_theta, true);
    }

    /// Backward over a sub-range [i, j) of grid steps (multi-observation
    /// losses add λ jumps between ranges — see tasks/stiff.rs).  Consumes
    /// the states in (i, j]; state `i` stays stored so the next (lower)
    /// range can use it as its right boundary.
    pub fn backward_range(
        &mut self,
        rhs: &dyn OdeRhs,
        i: usize,
        j: usize,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
    ) {
        self.backward_range_impl(rhs, i, j, lambda, grad_theta, false);
    }

    fn backward_range_impl(
        &mut self,
        rhs: &dyn OdeRhs,
        i: usize,
        j: usize,
        lambda: &mut [f32],
        grad_theta: &mut [f32],
        check_convergence: bool,
    ) {
        if i >= j {
            return;
        }
        self.store.begin_reverse_sweep();
        // pairs (step, step+1) walk down from j; each state's last use is
        // as the pair's lower end, so carry it over instead of re-reading
        let mut upper = self.store.take(j).expect("state stored").u;
        for step in (i..j).rev() {
            let t = self.ts[step];
            let h = self.ts[step + 1] - self.ts[step];
            let lower = if step == i {
                // boundary: a later backward_range call still needs it
                self.store.get(step).expect("state stored").u.clone()
            } else {
                self.store.take(step).expect("state stored").u
            };
            let res = adjoint_theta_step(
                self.scheme,
                rhs,
                t,
                h,
                &lower,
                &upper,
                lambda,
                grad_theta,
                &self.gmres_opts,
            );
            if check_convergence {
                debug_assert!(res.converged, "transposed solve stalled at step {step}");
            }
            let _ = res;
            upper = lower;
        }
        self.store.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Act;
    use crate::ode::rhs::MlpRhs;
    use crate::ode::tableau;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn mk_rhs(seed: u64) -> MlpRhs {
        let dims = vec![4, 7, 3];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.2);
        MlpRhs::new(dims, Act::Tanh, true, 2, theta)
    }

    /// gradient of L = <w, u(tF)> via a run with the given policy
    fn grad_with_policy(
        policy: CheckpointPolicy,
        rhs: &MlpRhs,
        u0: &[f32],
        w: &[f32],
        nt: usize,
    ) -> (Vec<f32>, Vec<f32>, u64) {
        let mut run = ErkAdjointRun::new(&tableau::RK4, policy, 0.0, 1.0, nt);
        run.forward(rhs, u0);
        let mut lambda = w.to_vec();
        let mut gtheta = vec![0.0f32; rhs.param_len()];
        run.backward(rhs, &mut lambda, &mut gtheta);
        (lambda, gtheta, run.recompute_steps)
    }

    #[test]
    fn policies_give_identical_gradients() {
        let rhs = mk_rhs(31);
        let n = rhs.state_len();
        let mut rng = Rng::new(32);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 12;

        let (l_all, g_all, r_all) = grad_with_policy(CheckpointPolicy::All, &rhs, &u0, &w, nt);
        let (l_sol, g_sol, r_sol) =
            grad_with_policy(CheckpointPolicy::SolutionOnly, &rhs, &u0, &w, nt);
        let (l_bin, g_bin, r_bin) = grad_with_policy(
            CheckpointPolicy::Binomial { n_checkpoints: 3 },
            &rhs,
            &u0,
            &w,
            nt,
        );

        assert_eq!(r_all, 0, "All policy recomputes nothing");
        assert_eq!(r_sol, (nt - 1) as u64, "SolutionOnly recomputes N_t - 1");
        assert!(r_bin > 0, "binomial with few slots must recompute");
        crate::testing::assert_allclose(&l_sol, &l_all, 1e-5, 1e-6, "λ sol vs all");
        crate::testing::assert_allclose(&g_sol, &g_all, 1e-5, 1e-6, "θ̄ sol vs all");
        crate::testing::assert_allclose(&l_bin, &l_all, 1e-5, 1e-6, "λ bin vs all");
        crate::testing::assert_allclose(&g_bin, &g_all, 1e-5, 1e-6, "θ̄ bin vs all");
    }

    #[test]
    fn binomial_recompute_matches_dp_prediction() {
        let rhs = mk_rhs(41);
        let n = rhs.state_len();
        let mut rng = Rng::new(42);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        for (nt, nc) in [(8usize, 2usize), (12, 3), (16, 2), (20, 5)] {
            let (_, _, recomputes) = grad_with_policy(
                CheckpointPolicy::Binomial { n_checkpoints: nc },
                &rhs,
                &u0,
                &w,
                nt,
            );
            let predicted = crate::checkpoint::binomial::optimal_extra_steps(nt, nc);
            assert_eq!(
                recomputes, predicted,
                "nt={nt} nc={nc}: executed {recomputes} != DP {predicted}"
            );
        }
    }

    fn tmp_spill_dir(tag: &str) -> String {
        let d = std::env::temp_dir()
            .join(format!("pnode-driver-tiered-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d.to_string_lossy().into_owned()
    }

    #[test]
    fn tiered_spill_gradients_are_bitwise_identical_to_in_memory() {
        let rhs = mk_rhs(71);
        let n = rhs.state_len();
        let mut rng = Rng::new(72);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 16;

        let (l_mem, g_mem, _) = grad_with_policy(CheckpointPolicy::All, &rhs, &u0, &w, nt);

        let dir = tmp_spill_dir("all");
        // budget far below one full trajectory: forces spilling
        let policy = CheckpointPolicy::Tiered {
            budget_bytes: 600,
            dir: dir.clone(),
            compress_f16: false,
            inner: Box::new(CheckpointPolicy::All),
        };
        let mut run = ErkAdjointRun::new(&tableau::RK4, policy, 0.0, 1.0, nt);
        run.forward(&rhs, &u0);
        let mut l_t = w.to_vec();
        let mut g_t = vec![0.0f32; rhs.param_len()];
        run.backward(&rhs, &mut l_t, &mut g_t);
        let st = run.tier_stats();

        assert_eq!(run.recompute_steps, 0, "All placement never recomputes");
        assert_eq!(l_t, l_mem, "λ bitwise identical across backends");
        assert_eq!(g_t, g_mem, "θ̄ bitwise identical across backends");
        assert!(st.spills > 0, "budget must force spills: {st:?}");
        assert!(st.prefetch_hits > 0, "reverse sweep must use the prefetcher: {st:?}");
        assert!(st.cold_bytes_written > 0);
        assert!(
            st.peak_hot_bytes <= 600 + 2 * 500,
            "hot tier stays near budget: {st:?}"
        );
        let _ = std::fs::remove_dir_all(std::path::Path::new(&dir));
    }

    #[test]
    fn tiered_composes_with_binomial_and_solution_only() {
        let rhs = mk_rhs(81);
        let n = rhs.state_len();
        let mut rng = Rng::new(82);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 12;
        let (l_ref, g_ref, _) = grad_with_policy(CheckpointPolicy::All, &rhs, &u0, &w, nt);

        for (tag, inner, want_recompute) in [
            ("bin", CheckpointPolicy::Binomial { n_checkpoints: 3 }, None),
            ("sol", CheckpointPolicy::SolutionOnly, Some((nt - 1) as u64)),
        ] {
            let dir = tmp_spill_dir(tag);
            let policy = CheckpointPolicy::Tiered {
                budget_bytes: 512,
                dir: dir.clone(),
                compress_f16: false,
                inner: Box::new(inner.clone()),
            };
            let (l, g, recompute) = grad_with_policy(policy, &rhs, &u0, &w, nt);
            assert_eq!(l, l_ref, "{tag}: λ bitwise vs in-memory All");
            assert_eq!(g, g_ref, "{tag}: θ̄ bitwise vs in-memory All");
            if let Some(want) = want_recompute {
                assert_eq!(recompute, want, "{tag}");
            }
            // recompute counts must match the same placement without tiers
            let (_, _, recompute_mem) = grad_with_policy(inner, &rhs, &u0, &w, nt);
            assert_eq!(recompute, recompute_mem, "{tag}: tiering never changes the schedule");
            let _ = std::fs::remove_dir_all(std::path::Path::new(&dir));
        }
    }

    #[test]
    fn tiered_f16_compression_accounts_error_and_stays_close() {
        let rhs = mk_rhs(91);
        let n = rhs.state_len();
        let mut rng = Rng::new(92);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 16;
        let (l_ref, g_ref, _) = grad_with_policy(CheckpointPolicy::All, &rhs, &u0, &w, nt);

        let dir = tmp_spill_dir("f16");
        let policy = CheckpointPolicy::Tiered {
            budget_bytes: 600,
            dir: dir.clone(),
            compress_f16: true,
            inner: Box::new(CheckpointPolicy::All),
        };
        let mut run = ErkAdjointRun::new(&tableau::RK4, policy, 0.0, 1.0, nt);
        run.forward(&rhs, &u0);
        let mut l = w.to_vec();
        let mut g = vec![0.0f32; rhs.param_len()];
        run.backward(&rhs, &mut l, &mut g);
        let st = run.tier_stats();
        assert!(st.compressed_elems > 0, "{st:?}");
        assert!(st.compress_max_abs_err > 0.0 && st.compress_max_abs_err < 5e-2, "{st:?}");
        // f16 state error (~5e-4 relative) propagates mildly into gradients
        crate::testing::assert_allclose(&l, &l_ref, 1e-1, 1e-3, "f16 λ");
        crate::testing::assert_allclose(&g, &g_ref, 1e-1, 1e-3, "f16 θ̄");
        let _ = std::fs::remove_dir_all(std::path::Path::new(&dir));
    }

    #[test]
    fn full_gradient_matches_finite_differences() {
        let mut rhs = mk_rhs(51);
        let n = rhs.state_len();
        let p = rhs.param_len();
        let mut rng = Rng::new(52);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let nt = 8;
        let (lambda, gtheta, _) =
            grad_with_policy(CheckpointPolicy::All, &rhs, &u0, &w, nt);

        let loss = |rhs: &dyn OdeRhs, u0: &[f32]| {
            let uf = crate::ode::erk::integrate_fixed(
                &tableau::RK4, rhs, 0.0, 1.0, nt, u0, |_, _, _, _, _, _| {},
            );
            crate::tensor::dot(&w, &uf)
        };
        let fd = 1e-3f32;
        for idx in 0..n.min(4) {
            let mut up = u0.clone();
            up[idx] += fd;
            let mut um = u0.clone();
            um[idx] -= fd;
            let d = (loss(&rhs, &up) - loss(&rhs, &um)) / (2.0 * fd as f64);
            assert!(
                (d - lambda[idx] as f64).abs() < 1e-2 * (1.0 + d.abs()),
                "dL/du[{idx}] {} vs fd {d}",
                lambda[idx]
            );
        }
        let theta0 = rhs.params().to_vec();
        for idx in [0usize, p / 2, p - 1] {
            let mut tp = theta0.clone();
            tp[idx] += fd;
            rhs.set_params(&tp);
            let lp = loss(&rhs, &u0);
            let mut tm = theta0.clone();
            tm[idx] -= fd;
            rhs.set_params(&tm);
            let lm = loss(&rhs, &u0);
            rhs.set_params(&theta0);
            let d = (lp - lm) / (2.0 * fd as f64);
            assert!(
                (d - gtheta[idx] as f64).abs() < 1e-2 * (1.0 + d.abs()),
                "dL/dθ[{idx}] {} vs fd {d}",
                gtheta[idx]
            );
        }
    }

    #[test]
    fn implicit_tiered_matches_in_memory_bitwise() {
        use crate::checkpoint::tiered::TieredConfig;
        let rhs = {
            let dims = vec![3, 8, 3];
            let mut rng = Rng::new(63);
            let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
            MlpRhs::new(dims, crate::nn::Act::Gelu, false, 1, theta)
        };
        let ts: Vec<f64> = (0..=12).map(|i| i as f64 / 12.0).collect();
        let u0 = vec![0.5f32, -0.2, 0.1];
        let w = vec![1.0f32, -0.5, 0.25];

        let grad = |run: &mut ImplicitAdjointRun| {
            run.forward(&rhs, &u0);
            let mut l = w.clone();
            let mut g = vec![0.0f32; rhs.param_len()];
            run.backward(&rhs, &mut l, &mut g);
            (l, g)
        };
        let mut mem = ImplicitAdjointRun::new(ThetaScheme::crank_nicolson(), ts.clone());
        let (l_mem, g_mem) = grad(&mut mem);

        let dir = tmp_spill_dir("implicit");
        // each state record is 3*4+48 = 60 B; 13 states ≈ 780 B total
        let mut tr = ImplicitAdjointRun::tiered(
            ThetaScheme::crank_nicolson(),
            ts,
            TieredConfig::new(150, &dir),
        )
        .expect("tiered store");
        let (l_t, g_t) = grad(&mut tr);
        let st = tr.tier_stats();

        assert_eq!(l_t, l_mem, "implicit λ bitwise identical across backends");
        assert_eq!(g_t, g_mem, "implicit θ̄ bitwise identical across backends");
        assert!(st.spills > 0, "150 B budget must spill: {st:?}");
        assert!(st.prefetch_hits > 0, "backward sweep prefetches: {st:?}");
        let _ = std::fs::remove_dir_all(std::path::Path::new(&dir));
    }

    #[test]
    fn implicit_run_gradient_matches_fd() {
        let mut rhs = {
            let dims = vec![3, 8, 3];
            let mut rng = Rng::new(61);
            let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
            MlpRhs::new(dims, Act::Gelu, false, 1, theta)
        };
        let ts = vec![0.0, 0.1, 0.25, 0.5, 1.0];
        let u0 = vec![0.5f32, -0.2, 0.1];
        let w = vec![1.0f32, -0.5, 0.25];

        let mut run = ImplicitAdjointRun::new(ThetaScheme::crank_nicolson(), ts.clone());
        run.forward(&rhs, &u0);
        let mut lambda = w.clone();
        let mut gtheta = vec![0.0f32; rhs.param_len()];
        run.backward(&rhs, &mut lambda, &mut gtheta);

        let loss = |rhs: &dyn OdeRhs, u0: &[f32]| {
            let uf = integrate_implicit_grid(
                ThetaScheme::crank_nicolson(),
                rhs,
                &ts,
                u0,
                |_, _, _, _, _| {},
            );
            crate::tensor::dot(&w, &uf)
        };
        let fd = 1e-3f32;
        for idx in 0..3 {
            let mut up = u0.clone();
            up[idx] += fd;
            let mut um = u0.clone();
            um[idx] -= fd;
            let d = (loss(&rhs, &up) - loss(&rhs, &um)) / (2.0 * fd as f64);
            assert!(
                (d - lambda[idx] as f64).abs() < 2e-2 * (1.0 + d.abs()),
                "dL/du[{idx}] {} vs fd {d}",
                lambda[idx]
            );
        }
        let p = rhs.param_len();
        let theta0 = rhs.params().to_vec();
        for idx in [0usize, p - 1] {
            let mut tp = theta0.clone();
            tp[idx] += fd;
            rhs.set_params(&tp);
            let lp = loss(&rhs, &u0);
            let mut tm = theta0.clone();
            tm[idx] -= fd;
            rhs.set_params(&tm);
            let lm = loss(&rhs, &u0);
            rhs.set_params(&theta0);
            let d = (lp - lm) / (2.0 * fd as f64);
            assert!(
                (d - gtheta[idx] as f64).abs() < 2e-2 * (1.0 + d.abs()),
                "dL/dθ[{idx}] {} vs fd {d}",
                gtheta[idx]
            );
        }
    }
}
