//! Discrete adjoint of the implicit theta-method (paper §3.3, eq. 13).
//!
//! Forward step (θ = 1 backward Euler, θ = ½ Crank–Nicolson):
//!   u_{n+1} = u_n + h (1−θ) f(t_n, u_n) + h θ f(t_{n+1}, u_{n+1})
//!
//! Adjoint: solve the *transposed* linear system
//!   (I − hθ ∂f/∂u(u_{n+1}))ᵀ λ_s = λ_{n+1}
//! with matrix-free GMRES whose operator is the VJP primitive, then
//!   λ_n = λ_s + h(1−θ) (∂f/∂u(u_n))ᵀ λ_s
//!   μ  += hθ (∂f/∂θ(u_{n+1}))ᵀ λ_s + h(1−θ) (∂f/∂θ(u_n))ᵀ λ_s.
//!
//! Only solutions need checkpointing for implicit steps (no stage vectors).

use crate::linalg::gmres::{gmres, GmresOptions, GmresResult};
use crate::ode::implicit::ThetaScheme;
use crate::ode::rhs::OdeRhs;
use crate::tensor;

/// Reverse one implicit theta step.  `lambda` enters as λ_{n+1}, leaves as
/// λ_n; `grad_theta` accumulates μ contributions.  Returns the GMRES stats
/// of the transposed solve.
#[allow(clippy::too_many_arguments)]
pub fn adjoint_theta_step(
    scheme: ThetaScheme,
    rhs: &dyn OdeRhs,
    t: f64,
    h: f64,
    u_n: &[f32],
    u_np1: &[f32],
    lambda: &mut [f32],
    grad_theta: &mut [f32],
    gmres_opts: &GmresOptions,
) -> GmresResult {
    let theta = scheme.theta;
    let n = u_n.len();
    let t1 = t + h;

    // transposed solve: (I - hθ Jᵀ(u_{n+1})) λ_s = λ_{n+1}
    let mut lambda_s = lambda.to_vec(); // warm start from λ_{n+1}
    let mut vjp_buf = vec![0.0f32; n];
    let res = {
        let op = |w: &[f32], out: &mut [f32]| {
            rhs.vjp_u(t1, u_np1, w, &mut vjp_buf);
            for i in 0..n {
                out[i] = w[i] - (h * theta) as f32 * vjp_buf[i];
            }
        };
        gmres(op, lambda, &mut lambda_s, gmres_opts)
    };

    // μ += hθ (∂f/∂θ(u_{n+1}))ᵀ λ_s   [+ h(1−θ) (∂f/∂θ(u_n))ᵀ λ_s]
    // and λ_n = λ_s + h(1−θ) Jᵀ(u_n) λ_s
    let mut scaled = lambda_s.clone();
    tensor::scal((h * theta) as f32, &mut scaled);
    let mut sink_u = vec![0.0f32; n];
    rhs.vjp_both(t1, u_np1, &scaled, &mut sink_u, grad_theta);

    lambda.copy_from_slice(&lambda_s);
    if theta < 1.0 {
        let mut scaled_n = lambda_s.clone();
        tensor::scal((h * (1.0 - theta)) as f32, &mut scaled_n);
        let mut gu = vec![0.0f32; n];
        rhs.vjp_both(t, u_n, &scaled_n, &mut gu, grad_theta);
        tensor::axpy(1.0, &gu, lambda);
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Act;
    use crate::ode::implicit::{ImplicitStepper, ThetaScheme};
    use crate::ode::ModuleRhs;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn mk_rhs(seed: u64) -> ModuleRhs {
        let dims = vec![3, 8, 3];
        let mut rng = Rng::new(seed);
        let theta = crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
        ModuleRhs::mlp(dims, Act::Tanh, false, 1, theta)
    }

    fn one_step_check(scheme: ThetaScheme, seed: u64) -> Result<(), String> {
        let mut rhs = mk_rhs(seed);
        let n = rhs.state_len();
        let p = rhs.param_len();
        let mut rng = Rng::new(seed ^ 0xABCD);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let (t, h) = (0.0, 0.1);

        let step = |rhs: &dyn OdeRhs, u0: &[f32]| -> Vec<f32> {
            let mut stepper = ImplicitStepper::new(scheme, n);
            let mut u1 = vec![0.0f32; n];
            stepper.step(rhs, t, h, u0, &mut u1);
            u1
        };

        let u1 = step(&rhs, &u0);
        let mut lambda = w.clone();
        let mut gtheta = vec![0.0f32; p];
        let res = adjoint_theta_step(
            scheme,
            &rhs,
            t,
            h,
            &u0,
            &u1,
            &mut lambda,
            &mut gtheta,
            &GmresOptions::default(),
        );
        if !res.converged {
            return Err("transposed GMRES did not converge".into());
        }

        let loss = |rhs: &dyn OdeRhs, u0: &[f32]| crate::tensor::dot(&w, &step(rhs, u0));
        let fd = 1e-3f32;
        for idx in 0..n {
            let mut up = u0.clone();
            up[idx] += fd;
            let mut um = u0.clone();
            um[idx] -= fd;
            let d = (loss(&rhs, &up) - loss(&rhs, &um)) / (2.0 * fd as f64);
            if (d - lambda[idx] as f64).abs() > 1e-2 * (1.0 + d.abs()) {
                return Err(format!(
                    "{}: dL/du[{idx}] {} vs fd {d}",
                    scheme.name, lambda[idx]
                ));
            }
        }
        let theta0 = rhs.params().to_vec();
        for idx in [0usize, p / 3, p - 1] {
            let mut tp = theta0.clone();
            tp[idx] += fd;
            rhs.set_params(&tp);
            let lp = loss(&rhs, &u0);
            let mut tm = theta0.clone();
            tm[idx] -= fd;
            rhs.set_params(&tm);
            let lm = loss(&rhs, &u0);
            rhs.set_params(&theta0);
            let d = (lp - lm) / (2.0 * fd as f64);
            if (d - gtheta[idx] as f64).abs() > 1e-2 * (1.0 + d.abs()) {
                return Err(format!(
                    "{}: dL/dθ[{idx}] {} vs fd {d}",
                    scheme.name, gtheta[idx]
                ));
            }
        }
        Ok(())
    }

    #[test]
    fn backward_euler_adjoint_matches_fd() {
        prop::check("be-adjoint", 23, 4, |rng| {
            one_step_check(ThetaScheme::backward_euler(), rng.next_u64())
        });
    }

    #[test]
    fn crank_nicolson_adjoint_matches_fd() {
        prop::check("cn-adjoint", 29, 4, |rng| {
            one_step_check(ThetaScheme::crank_nicolson(), rng.next_u64())
        });
    }
}
