//! Discrete adjoint of one explicit Runge–Kutta step — literal reverse-mode
//! differentiation of the step map, hence reverse-accurate by construction
//! (paper §2.2, eq. 7, and Table 1 for the Euler special case).
//!
//! Forward step:
//!   U_i = u_n + h Σ_{j<i} a_ij k_j,   k_i = f(t_n + c_i h, U_i),
//!   u_{n+1} = u_n + h Σ_i b_i k_i.
//!
//! Reverse (cotangent λ = ū_{n+1}):
//!   k̄_i = h b_i λ + h Σ_{j>i} a_ji Ū_j            (processed i = s-1 … 0)
//!   Ū_i = (∂f/∂u(U_i))ᵀ k̄_i,    θ̄ += (∂f/∂θ(U_i))ᵀ k̄_i
//!   λ_n = λ + Σ_i Ū_i.
//!
//! Requires the stage derivatives `ks` of the forward step; stage states
//! are reconstructed with pure linear algebra (no extra NFE).

use crate::ode::erk::stage_state;
use crate::ode::rhs::OdeRhs;
use crate::ode::tableau::Tableau;
use crate::tensor;

/// Reusable buffers: adjoint of a step allocates nothing.
pub struct AdjointErkWorkspace {
    /// Ū_i per stage
    ubars: Vec<Vec<f32>>,
    /// k̄ for the current stage
    kbar: Vec<f32>,
    /// reconstructed stage state
    ustage: Vec<f32>,
}

impl AdjointErkWorkspace {
    pub fn new(s: usize, n: usize) -> Self {
        AdjointErkWorkspace {
            ubars: (0..s).map(|_| vec![0.0; n]).collect(),
            kbar: vec![0.0; n],
            ustage: vec![0.0; n],
        }
    }
}

/// Reverse one ERK step: `lambda` enters as λ_{n+1}, leaves as λ_n;
/// `grad_theta` accumulates θ̄.  Costs `s` backward NFE (one fused
/// `vjp_both` per stage).
#[allow(clippy::too_many_arguments)]
pub fn adjoint_erk_step(
    tab: &Tableau,
    rhs: &dyn OdeRhs,
    t: f64,
    h: f64,
    u: &[f32],
    ks: &[Vec<f32>],
    lambda: &mut [f32],
    grad_theta: &mut [f32],
    ws: &mut AdjointErkWorkspace,
) {
    let s = tab.s;
    debug_assert_eq!(ks.len(), s);
    for i in (0..s).rev() {
        // k̄_i = h b_i λ + h Σ_{j>i} a_ji Ū_j
        let kbar = &mut ws.kbar;
        tensor::zero(kbar);
        if tab.b[i] != 0.0 {
            tensor::axpy((h * tab.b[i]) as f32, lambda, kbar);
        }
        for j in i + 1..s {
            let a = tab.a(j, i);
            if a != 0.0 {
                tensor::axpy((h * a) as f32, &ws.ubars[j], kbar);
            }
        }
        // skip stages with zero cotangent (e.g. FSAL stage with b_s = 0 and
        // no dependents): saves a VJP without changing the result
        if tensor::nrm_inf(kbar) == 0.0 {
            tensor::zero(&mut ws.ubars[i]);
            continue;
        }
        // Ū_i = (∂f/∂u)ᵀ k̄_i at the reconstructed stage state
        stage_state(tab, i, h, u, ks, &mut ws.ustage);
        let ti = t + tab.c[i] * h;
        let (kbar_ref, ubar_i) = (&ws.kbar, &mut ws.ubars[i]);
        rhs.vjp_both(ti, &ws.ustage, kbar_ref, ubar_i, grad_theta);
    }
    // λ_n = λ + Σ_i Ū_i
    for ubar in &ws.ubars {
        tensor::axpy(1.0, ubar, lambda);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Act;
    use crate::ode::erk::{erk_step, ErkWorkspace};
    use crate::ode::ModuleRhs;
    use crate::ode::rhs::LinearRhs;
    use crate::ode::tableau;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    /// One-step gradient check: L = <w, u_1>; dL/du_0 and dL/dθ vs FD.
    fn one_step_check(tab: &Tableau, rhs: &mut dyn OdeRhs, seed: u64) -> Result<(), String> {
        let n = rhs.state_len();
        let p = rhs.param_len();
        let mut rng = Rng::new(seed);
        let u0 = prop::vec_uniform(&mut rng, n, 0.5);
        let w = prop::vec_uniform(&mut rng, n, 1.0);
        let (t, h) = (0.1, 0.05);

        let mut ks: Vec<Vec<f32>> = (0..tab.s).map(|_| vec![0.0f32; n]).collect();
        let mut u1 = vec![0.0f32; n];
        let mut ews = ErkWorkspace::new(n);
        erk_step(tab, rhs, t, h, &u0, &mut ks, &mut u1, &mut ews, None);

        let mut lambda = w.clone();
        let mut gtheta = vec![0.0f32; p];
        let mut aws = AdjointErkWorkspace::new(tab.s, n);
        adjoint_erk_step(tab, rhs, t, h, &u0, &ks, &mut lambda, &mut gtheta, &mut aws);

        let loss = |rhs: &dyn OdeRhs, u0: &[f32]| -> f64 {
            let mut ks: Vec<Vec<f32>> = (0..tab.s).map(|_| vec![0.0f32; n]).collect();
            let mut u1 = vec![0.0f32; n];
            let mut ews = ErkWorkspace::new(n);
            erk_step(tab, rhs, t, h, u0, &mut ks, &mut u1, &mut ews, None);
            crate::tensor::dot(&w, &u1)
        };

        let fd = 1e-3f32;
        for idx in 0..n.min(5) {
            let mut up = u0.clone();
            up[idx] += fd;
            let mut um = u0.clone();
            um[idx] -= fd;
            let d = (loss(rhs, &up) - loss(rhs, &um)) / (2.0 * fd as f64);
            if (d - lambda[idx] as f64).abs() > 5e-3 * (1.0 + d.abs()) {
                return Err(format!("{}: dL/du[{idx}] {} vs fd {d}", tab.name, lambda[idx]));
            }
        }
        let theta0 = rhs.params().to_vec();
        for idx in [0, p / 2, p - 1] {
            let mut tp = theta0.clone();
            tp[idx] += fd;
            rhs.set_params(&tp);
            let lp = loss(rhs, &u0);
            let mut tm = theta0.clone();
            tm[idx] -= fd;
            rhs.set_params(&tm);
            let lm = loss(rhs, &u0);
            rhs.set_params(&theta0);
            let d = (lp - lm) / (2.0 * fd as f64);
            if (d - gtheta[idx] as f64).abs() > 5e-3 * (1.0 + d.abs()) {
                return Err(format!("{}: dL/dθ[{idx}] {} vs fd {d}", tab.name, gtheta[idx]));
            }
        }
        Ok(())
    }

    #[test]
    fn one_step_adjoint_matches_fd_all_schemes() {
        for tab in [
            &tableau::EULER,
            &tableau::MIDPOINT,
            &tableau::BOSH3,
            &tableau::RK4,
            &tableau::DOPRI5,
        ] {
            prop::check(&format!("erk-adjoint-{}", tab.name), 17, 3, |rng| {
                let dims = vec![4, 6, 3];
                let theta =
                    crate::nn::init::kaiming_uniform(&mut rng.fork(1), &dims, 1.0);
                let mut rhs = ModuleRhs::mlp(dims, Act::Tanh, true, 2, theta);
                one_step_check(tab, &mut rhs, rng.next_u64())
            });
        }
    }

    #[test]
    fn linear_system_adjoint_is_exact_transpose() {
        // For du/dt = A u and Euler: u1 = (I + hA) u0, so λ0 = (I + hA)ᵀ λ1
        let d = 3;
        let mut rng = Rng::new(3);
        let a = prop::vec_normal(&mut rng, d * d);
        let rhs = LinearRhs::new(d, a.clone());
        let tab = &tableau::EULER;
        let u0 = prop::vec_normal(&mut rng, d);
        let lam1 = prop::vec_normal(&mut rng, d);
        let h = 0.05f64;

        let mut ks = vec![vec![0.0f32; d]];
        let mut u1 = vec![0.0f32; d];
        let mut ews = ErkWorkspace::new(d);
        erk_step(tab, &rhs, 0.0, h, &u0, &mut ks, &mut u1, &mut ews, None);

        let mut lambda = lam1.clone();
        let mut gtheta = vec![0.0f32; d * d];
        let mut aws = AdjointErkWorkspace::new(1, d);
        adjoint_erk_step(tab, &rhs, 0.0, h, &u0, &ks, &mut lambda, &mut gtheta, &mut aws);

        // expected (I + hA)ᵀ λ1
        let mut want = lam1.clone();
        for j in 0..d {
            let mut acc = 0.0f32;
            for i in 0..d {
                acc += a[i * d + j] * lam1[i];
            }
            want[j] += h as f32 * acc;
        }
        crate::testing::assert_allclose(&lambda, &want, 1e-5, 1e-6, "euler exact transpose");
    }
}
