//! `xla`-free stand-ins for [`Client`] and [`Executable`].
//!
//! Compiled when the `xla` feature is off (the default: the `xla` crate
//! needs libxla_extension, unavailable in offline builds).  `Client::cpu()`
//! fails with a clear message, so every artifact-gated code path — the
//! `xla_runtime` tests, the PJRT micro-benches, `pnode info` — degrades to
//! its documented "artifacts not available" behaviour.  The pure-Rust
//! `ModuleRhs` mirror covers the full algorithmic surface without it.

use anyhow::{bail, Result};

const MSG: &str = "pnode was built without the `xla` feature; \
                   PJRT execution is unavailable (enable with \
                   `--features xla` and the `xla` dependency — see Cargo.toml)";

/// Stub PJRT client: construction always fails.
#[derive(Clone)]
pub struct Client;

impl Client {
    pub fn cpu() -> Result<Self> {
        bail!(MSG)
    }

    pub fn platform_name(&self) -> String {
        "xla-disabled".into()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile_hlo_text(
        &self,
        _path: &std::path::Path,
        _name: &str,
        _arg_shapes: Vec<Vec<usize>>,
    ) -> Result<Executable> {
        bail!(MSG)
    }
}

/// Stub executable: never constructible (no `Client` can exist to compile
/// one), so the methods only keep the call sites type-checking.
pub struct Executable {
    name: String,
    arg_shapes: Vec<Vec<usize>>,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn arg_shapes(&self) -> &[Vec<usize>] {
        &self.arg_shapes
    }

    pub fn call_count(&self) -> u64 {
        0
    }

    pub fn reset_call_count(&self) {}

    pub fn call(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        bail!(MSG)
    }

    pub fn call_into(&self, _inputs: &[&[f32]], _out: &mut [f32]) -> Result<()> {
        bail!(MSG)
    }
}
