//! Manifest parsing and per-config artifact loading.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

use super::{Client, Executable};

/// One model configuration from `manifest.json`.
#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub name: String,
    /// "mlp" (classification / stiff) or "cnf" (FFJORD augmented dynamics)
    pub kind: String,
    /// layer widths of the RHS MLP (input includes +1 when `time_dep`)
    pub dims: Vec<usize>,
    pub act: String,
    pub time_dep: bool,
    pub batch: usize,
    pub state_dim: usize,
    pub param_count: usize,
    /// primitive suffix -> artifact file name
    pub artifacts: BTreeMap<String, String>,
    /// primitive suffix -> argument shapes
    pub arg_shapes: BTreeMap<String, Vec<Vec<usize>>>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigEntry>,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = json::parse(&text).context("parsing manifest.json")?;
        let version = root.req("version")?.as_usize().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut configs = BTreeMap::new();
        for (name, cfg) in root.req("configs")?.as_obj().unwrap_or(&[]) {
            configs.insert(name.clone(), parse_config(name, cfg)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), configs })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Manifest> {
        Self::load(&super::artifacts_dir())
    }

    pub fn config(&self, name: &str) -> Result<&ConfigEntry> {
        self.configs.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "config {name:?} not in manifest (have: {:?})",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }
}

fn parse_config(name: &str, cfg: &Json) -> Result<ConfigEntry> {
    let str_of = |key: &str| -> Result<String> {
        Ok(cfg.req(key)?.as_str().context(key.to_string())?.to_string())
    };
    let usize_of = |key: &str| -> Result<usize> {
        cfg.req(key)?.as_usize().with_context(|| key.to_string())
    };
    let mut artifacts = BTreeMap::new();
    for (k, v) in cfg.req("artifacts")?.as_obj().unwrap_or(&[]) {
        artifacts.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
    }
    let mut arg_shapes = BTreeMap::new();
    for (k, v) in cfg.req("arg_shapes")?.as_obj().unwrap_or(&[]) {
        let shapes = v
            .as_arr()
            .context("arg_shapes entry not an array")?
            .iter()
            .map(|s| s.as_usize_vec().context("bad shape"))
            .collect::<Result<Vec<_>>>()?;
        arg_shapes.insert(k.clone(), shapes);
    }
    Ok(ConfigEntry {
        name: name.to_string(),
        kind: str_of("kind")?,
        dims: cfg.req("dims")?.as_usize_vec().context("dims")?,
        act: str_of("act")?,
        time_dep: cfg.req("time_dep")?.as_bool().context("time_dep")?,
        batch: usize_of("batch")?,
        state_dim: usize_of("state_dim")?,
        param_count: usize_of("param_count")?,
        artifacts,
        arg_shapes,
    })
}

/// The compiled executables for one model config.
///
/// Primitives are compiled eagerly at construction (compilation is a few
/// hundred ms each; we pay it once at startup, never on the hot path).
pub struct ModelArtifacts {
    pub entry: ConfigEntry,
    executables: BTreeMap<String, Executable>,
}

impl ModelArtifacts {
    /// Compile every primitive listed in the manifest for `config`.
    pub fn load(client: &Client, manifest: &Manifest, config: &str) -> Result<Self> {
        let entry = manifest.config(config)?.clone();
        let mut executables = BTreeMap::new();
        for (suffix, file) in &entry.artifacts {
            let shapes = entry
                .arg_shapes
                .get(suffix)
                .with_context(|| format!("no arg_shapes for {suffix}"))?
                .clone();
            let path = manifest.dir.join(file);
            let name = format!("{config}.{suffix}");
            let exe = client.compile_hlo_text(&path, &name, shapes)?;
            executables.insert(suffix.clone(), exe);
        }
        Ok(ModelArtifacts { entry, executables })
    }

    pub fn get(&self, suffix: &str) -> Result<&Executable> {
        self.executables.get(suffix).ok_or_else(|| {
            anyhow::anyhow!(
                "{}: primitive {suffix:?} not loaded (have {:?})",
                self.entry.name,
                self.executables.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Total executable invocations across all primitives.
    pub fn total_calls(&self) -> u64 {
        self.executables.values().map(|e| e.call_count()).sum()
    }

    pub fn reset_call_counts(&self) {
        for e in self.executables.values() {
            e.reset_call_count();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_snippet() {
        let text = r#"{"version":1,"configs":{"quick_d8":{
            "kind":"mlp","dims":[9,16,8],"act":"tanh","time_dep":true,
            "batch":4,"state_dim":8,"param_count":296,
            "artifacts":{"f":"quick_d8.f.hlo.txt"},
            "arg_shapes":{"f":[[4,8],[296],[1]]}}}}"#;
        let root = json::parse(text).unwrap();
        let cfg = root.get("configs").unwrap().get("quick_d8").unwrap();
        let entry = parse_config("quick_d8", cfg).unwrap();
        assert_eq!(entry.kind, "mlp");
        assert_eq!(entry.dims, vec![9, 16, 8]);
        assert!(entry.time_dep);
        assert_eq!(entry.param_count, 296);
        assert_eq!(entry.arg_shapes["f"], vec![vec![4, 8], vec![296], vec![1]]);
    }
}
