//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate.  The compile path
//! (`python/compile/aot.py`) writes `artifacts/*.hlo.txt` plus
//! `artifacts/manifest.json`; [`Manifest`] parses the manifest,
//! [`ModelArtifacts`] compiles the executables for one model config, and
//! [`Executable::call`] runs one primitive with flat `f32` slices in/out.
//!
//! Interchange is HLO *text*: jax >= 0.5 emits HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

#[cfg(feature = "xla")]
mod client;
#[cfg(feature = "xla")]
mod executable;
mod manifest;
#[cfg(not(feature = "xla"))]
mod stub;

#[cfg(feature = "xla")]
pub use client::Client;
#[cfg(feature = "xla")]
pub use executable::Executable;
#[cfg(not(feature = "xla"))]
pub use stub::{Client, Executable};
pub use manifest::{ConfigEntry, Manifest, ModelArtifacts};

/// Default artifacts directory, overridable with the PNODE_ARTIFACTS env var.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("PNODE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
