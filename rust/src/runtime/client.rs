//! PJRT client handle.

use anyhow::{Context, Result};

/// Thin wrapper around [`xla::PjRtClient`] so the rest of the crate never
/// imports `xla` directly.  Cheap to clone (the underlying client is
/// refcounted).
#[derive(Clone)]
pub struct Client {
    pub(crate) inner: xla::PjRtClient,
}

impl Client {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let inner = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Client { inner })
    }

    pub fn platform_name(&self) -> String {
        self.inner.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    /// Load an HLO-text file and compile it into an [`super::Executable`].
    pub fn compile_hlo_text(
        &self,
        path: &std::path::Path,
        name: &str,
        arg_shapes: Vec<Vec<usize>>,
    ) -> Result<super::Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(super::Executable::new(name.to_string(), exe, arg_shapes))
    }
}
