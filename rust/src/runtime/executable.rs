//! A compiled HLO primitive, callable with flat f32 slices.

use std::cell::Cell;

use anyhow::{bail, Context, Result};

/// One compiled artifact (e.g. `clf_d64.vjp_both`).
///
/// All our artifacts take N f32 arrays and return a tuple of f32 arrays
/// (lowered with `return_tuple=True`).  `call` shape-checks inputs against
/// the manifest, executes, and flattens the outputs back to `Vec<f32>`.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    arg_shapes: Vec<Vec<usize>>,
    /// number of invocations (feeds NFE accounting)
    calls: Cell<u64>,
}

impl Executable {
    pub(crate) fn new(
        name: String,
        exe: xla::PjRtLoadedExecutable,
        arg_shapes: Vec<Vec<usize>>,
    ) -> Self {
        Executable { name, exe, arg_shapes, calls: Cell::new(0) }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn arg_shapes(&self) -> &[Vec<usize>] {
        &self.arg_shapes
    }

    pub fn call_count(&self) -> u64 {
        self.calls.get()
    }

    pub fn reset_call_count(&self) {
        self.calls.set(0)
    }

    /// Execute with flat f32 inputs; returns the tuple elements flattened.
    pub fn call(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.arg_shapes.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.name,
                self.arg_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().zip(&self.arg_shapes).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!(
                    "{}: arg {i} has {} elements, manifest shape {:?} wants {want}",
                    self.name,
                    data.len(),
                    shape
                );
            }
            // SAFETY: reinterprets the f32 slice as its own bytes — same
            // allocation, same length in bytes (len * 4), and u8 has no
            // alignment or validity requirements
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                shape,
                bytes,
            )
            .with_context(|| format!("{}: building literal for arg {i}", self.name))?;
            literals.push(lit);
        }

        self.calls.set(self.calls.get() + 1);
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("{}: reading result", self.name))?;
        let parts = tuple
            .to_tuple()
            .with_context(|| format!("{}: untupling result", self.name))?;
        let mut out = Vec::with_capacity(parts.len());
        for (i, part) in parts.into_iter().enumerate() {
            let v = part
                .to_vec::<f32>()
                .with_context(|| format!("{}: output {i} to_vec", self.name))?;
            out.push(v);
        }
        Ok(out)
    }

    /// Execute and write the single output into `out` (hot-path variant,
    /// avoids one Vec allocation when the primitive returns one array).
    pub fn call_into(&self, inputs: &[&[f32]], out: &mut [f32]) -> Result<()> {
        let results = self.call(inputs)?;
        if results.len() != 1 {
            bail!("{}: call_into expects 1 output, got {}", self.name, results.len());
        }
        if results[0].len() != out.len() {
            bail!(
                "{}: output has {} elements, destination {}",
                self.name,
                results[0].len(),
                out.len()
            );
        }
        out.copy_from_slice(&results[0]);
        Ok(())
    }
}
