//! Background reverse-order prefetcher for the adjoint sweep.
//!
//! At `begin_reverse_sweep` the tiered store snapshots its cold index in
//! descending step order and hands it to a thread that decodes records and
//! pushes them through a bounded channel.  The backward sweep consumes
//! checkpoints from step `N_t - 1` downward, so by the time the driver asks
//! for a spilled step the decode is usually already done — disk latency
//! hides behind stage recomputation.  The `sync_channel` capacity is the
//! read-ahead window: the thread blocks once it is `window` records ahead,
//! bounding prefetch RAM.
//!
//! Delivery order is exactly the snapshot order, which lets the consumer
//! make a precise choice per lookup: if the wanted step is still ahead in
//! `pending`, block on the channel (the record is in flight); otherwise
//! fall back to a synchronous [`super::cold::read_record`].

use std::collections::{BTreeSet, VecDeque};
use std::fs::File;
use std::path::Path;
use std::sync::mpsc::{sync_channel, Receiver, TryRecvError};
use std::thread::JoinHandle;

use super::cold::{read_record, RecordMeta};
use crate::checkpoint::store::StepCheckpoint;

pub struct Prefetcher {
    /// `Option` so `Drop` can disconnect the channel before joining
    rx: Option<Receiver<StepCheckpoint>>,
    /// steps not yet received, in delivery order (descending)
    pending: VecDeque<usize>,
    /// steps whose snapshot record was superseded after spawn; their
    /// deliveries are dropped instead of returned (stale payloads)
    invalid: BTreeSet<usize>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn a reader thread over its own handle on the spill file.  The
    /// caller must have flushed the writer first.  `records` must be the
    /// order the consumer will (mostly) want: descending step.
    pub fn spawn(
        path: &Path,
        records: Vec<RecordMeta>,
        window: usize,
    ) -> std::io::Result<Prefetcher> {
        let pending: VecDeque<usize> = records.iter().map(|r| r.step).collect();
        let mut file = File::open(path)?;
        let (tx, rx) = sync_channel::<StepCheckpoint>(window.max(1));
        let handle = std::thread::Builder::new()
            .name("pnode-ckpt-prefetch".into())
            .spawn(move || {
                for meta in &records {
                    match read_record(&mut file, meta) {
                        // receiver gone: sweep finished early, just exit
                        Err(_) => return, // consumer falls back to sync reads
                        Ok(cp) => {
                            if tx.send(cp).is_err() {
                                return;
                            }
                        }
                    }
                }
            })?;
        Ok(Prefetcher { rx: Some(rx), pending, invalid: BTreeSet::new(), handle: Some(handle) })
    }

    /// The consumer bypassed or replaced the cold record for `step`
    /// (synchronous read, or a fresh insert superseding it): stop
    /// advertising it and drop its delivery when it arrives — the
    /// in-flight payload is stale.
    pub fn invalidate(&mut self, step: usize) {
        if let Some(pos) = self.pending.iter().position(|&s| s == step) {
            let _ = self.pending.remove(pos);
            self.invalid.insert(step);
        }
    }

    /// Largest step still in flight (delivery is descending, so this is
    /// the next record the thread will hand over).
    pub fn next_pending(&self) -> Option<usize> {
        self.pending.front().copied()
    }

    /// Whether `step` is still ahead in the delivery queue.
    pub fn will_deliver(&self, step: usize) -> bool {
        // pending is descending; anything <= front may still arrive
        self.pending.iter().any(|&s| s == step)
    }

    /// Non-blocking receive.  Stale (invalidated) deliveries are dropped,
    /// never returned.
    pub fn try_recv(&mut self) -> Option<StepCheckpoint> {
        loop {
            let recv = match self.rx.as_ref() {
                Some(rx) => rx.try_recv(),
                None => return None,
            };
            match recv {
                Ok(cp) => {
                    if self.invalid.remove(&cp.step) {
                        continue; // superseded while in flight
                    }
                    self.mark_received(cp.step);
                    return Some(cp);
                }
                Err(TryRecvError::Empty) => return None,
                Err(TryRecvError::Disconnected) => {
                    self.rx = None;
                    self.pending.clear();
                    return None;
                }
            }
        }
    }

    /// Blocking receive; `None` when the thread is done (or died — the
    /// consumer then falls back to synchronous reads).  Stale
    /// (invalidated) deliveries are dropped, never returned.
    pub fn recv(&mut self) -> Option<StepCheckpoint> {
        loop {
            let recv = match self.rx.as_ref() {
                Some(rx) => rx.recv(),
                None => return None,
            };
            match recv {
                Ok(cp) => {
                    if self.invalid.remove(&cp.step) {
                        continue; // superseded while in flight
                    }
                    self.mark_received(cp.step);
                    return Some(cp);
                }
                Err(_) => {
                    self.rx = None;
                    self.pending.clear();
                    return None;
                }
            }
        }
    }

    fn mark_received(&mut self, step: usize) {
        // delivery matches `pending` front-to-back by construction; be
        // defensive anyway
        if self.pending.front() == Some(&step) {
            self.pending.pop_front();
        } else if let Some(pos) = self.pending.iter().position(|&s| s == step) {
            let _ = self.pending.remove(pos);
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // disconnect first so a blocked `send` in the thread errors out
        self.rx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::tiered::cold::ColdStore;
    use crate::util::rng::Rng;

    fn spilled_store(n_records: usize, n: usize) -> (ColdStore, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("pnode-prefetch-test-{}-{n_records}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cold = ColdStore::create(&dir, false).unwrap();
        let mut rng = Rng::new(5);
        for step in 0..n_records {
            let mut u = vec![0.0f32; n];
            rng.fill_normal(&mut u);
            cold.append(&StepCheckpoint { step, t: step as f64, h: 1.0, u, ks: None })
                .unwrap();
        }
        cold.flush().unwrap();
        (cold, dir)
    }

    #[test]
    fn delivers_all_records_in_reverse_order() {
        let (cold, dir) = spilled_store(12, 33);
        let mut pf = Prefetcher::spawn(cold.path(), cold.snapshot_desc(), 3).unwrap();
        let mut got = Vec::new();
        while let Some(cp) = pf.recv() {
            got.push(cp.step);
        }
        assert_eq!(got, (0..12).rev().collect::<Vec<_>>());
        assert_eq!(pf.next_pending(), None);
        drop(cold);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn will_deliver_tracks_pending() {
        let (cold, dir) = spilled_store(4, 8);
        let mut pf = Prefetcher::spawn(cold.path(), cold.snapshot_desc(), 2).unwrap();
        assert!(pf.will_deliver(0) && pf.will_deliver(3));
        let first = pf.recv().unwrap();
        assert_eq!(first.step, 3);
        assert!(!pf.will_deliver(3));
        assert!(pf.will_deliver(0));
        drop(pf); // joins the thread even with records unconsumed
        drop(cold);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn invalidated_steps_are_never_delivered() {
        let (cold, dir) = spilled_store(5, 9);
        let mut pf = Prefetcher::spawn(cold.path(), cold.snapshot_desc(), 2).unwrap();
        pf.invalidate(3);
        assert!(!pf.will_deliver(3), "invalidated step no longer advertised");
        let mut got = Vec::new();
        while let Some(cp) = pf.recv() {
            got.push(cp.step);
        }
        assert_eq!(got, vec![4, 2, 1, 0], "stale delivery dropped, order kept");
        drop(cold);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetched_payload_matches_store_read() {
        let (mut cold, dir) = spilled_store(6, 17);
        let direct = cold.read(4).unwrap().unwrap();
        let mut pf = Prefetcher::spawn(cold.path(), cold.snapshot_desc(), 2).unwrap();
        let mut found = None;
        while let Some(cp) = pf.recv() {
            if cp.step == 4 {
                found = Some(cp);
            }
        }
        assert_eq!(found.unwrap().u, direct.u, "prefetch path is bit-identical");
        drop(cold);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
