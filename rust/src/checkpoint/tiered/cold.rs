//! File-backed cold tier: compact binary step-checkpoint records.
//!
//! One append-only spill file per store, with an in-memory index
//! (`step -> RecordMeta`).  Record layout (little-endian):
//!
//! ```text
//! [magic u32 = 0x504e434b "PNCK"] [step u64] [t f64] [h f64]
//! [u_len u32] [n_stages u32] [stage_len u32] [encoding u8] [pad u8;3]
//! [payload: u then stages, row-major; f32 LE or f16 LE per `encoding`]
//! ```
//!
//! The index is never persisted: the spill file lives exactly as long as
//! one forward+backward pass and is deleted on drop.  f16 compression is
//! lossy; the codec accounts the exact round-trip error it introduces
//! (`compressed_elems`, `max_abs_err`) so benchmarks can report the
//! gradient-accuracy cost alongside the 2× byte saving.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::checkpoint::store::StepCheckpoint;

const RECORD_MAGIC: u32 = 0x504e_434b; // "PNCK"
const HEADER_BYTES: u64 = 4 + 8 + 8 + 8 + 4 + 4 + 4 + 4;

/// Payload element encoding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    F32,
    F16,
}

impl Encoding {
    fn elem_bytes(self) -> u64 {
        match self {
            Encoding::F32 => 4,
            Encoding::F16 => 2,
        }
    }

    fn tag(self) -> u8 {
        match self {
            Encoding::F32 => 0,
            Encoding::F16 => 1,
        }
    }

    fn from_tag(tag: u8) -> Option<Encoding> {
        match tag {
            0 => Some(Encoding::F32),
            1 => Some(Encoding::F16),
            _ => None,
        }
    }
}

/// Index entry: everything needed to read one record back without
/// consulting the writer.
#[derive(Clone, Copy, Debug)]
pub struct RecordMeta {
    pub step: usize,
    pub offset: u64,
    pub t: f64,
    pub h: f64,
    pub u_len: u32,
    pub n_stages: u32,
    pub stage_len: u32,
    pub encoding: Encoding,
}

impl RecordMeta {
    pub fn elems(&self) -> u64 {
        self.u_len as u64 + self.n_stages as u64 * self.stage_len as u64
    }

    pub fn payload_bytes(&self) -> u64 {
        self.elems() * self.encoding.elem_bytes()
    }

    pub fn total_bytes(&self) -> u64 {
        HEADER_BYTES + self.payload_bytes()
    }
}

/// The cold tier: appends at the tail, reads anywhere, deletes its file on
/// drop.
pub struct ColdStore {
    path: PathBuf,
    writer: BufWriter<File>,
    reader: File,
    index: BTreeMap<usize, RecordMeta>,
    write_offset: u64,
    writer_dirty: bool,
    compress: bool,
    // ---- counters ----
    pub bytes_written: u64,
    pub live_bytes: u64,
    pub spills: u64,
    pub compressed_elems: u64,
    pub compress_max_abs_err: f32,
}

static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

impl ColdStore {
    /// Create a fresh spill file under `dir` (created if absent).  The file
    /// name embeds the pid and a process-wide sequence number so concurrent
    /// stores never collide.
    pub fn create(dir: &Path, compress: bool) -> io::Result<ColdStore> {
        std::fs::create_dir_all(dir)?;
        // Relaxed: the RMW only needs to mint distinct file-name suffixes;
        // nothing is published through this counter
        let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!("pnode-spill-{}-{}.ckpt", std::process::id(), seq));
        let write_file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)?;
        let reader = File::open(&path)?;
        Ok(ColdStore {
            path,
            writer: BufWriter::new(write_file),
            reader,
            index: BTreeMap::new(),
            write_offset: 0,
            writer_dirty: false,
            compress,
            bytes_written: 0,
            live_bytes: 0,
            spills: 0,
            compressed_elems: 0,
            compress_max_abs_err: 0.0,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn contains(&self, step: usize) -> bool {
        self.index.contains_key(&step)
    }

    /// Live record metadata in descending step order — the order the
    /// backward sweep will want them back.
    pub fn snapshot_desc(&self) -> Vec<RecordMeta> {
        self.index.values().rev().copied().collect()
    }

    /// Append one checkpoint.  Replaces any index entry for the same step
    /// (the old record becomes dead space in the file; spill files live for
    /// one pass, so we trade compaction for strictly sequential writes).
    pub fn append(&mut self, cp: &StepCheckpoint) -> io::Result<()> {
        let (n_stages, stage_len) = match &cp.ks {
            Some(ks) => (ks.len() as u32, ks.first().map(|k| k.len()).unwrap_or(0) as u32),
            None => (0u32, 0u32),
        };
        let encoding = if self.compress { Encoding::F16 } else { Encoding::F32 };
        let meta = RecordMeta {
            step: cp.step,
            offset: self.write_offset,
            t: cp.t,
            h: cp.h,
            u_len: cp.u.len() as u32,
            n_stages,
            stage_len,
            encoding,
        };

        fn write_slice(
            w: &mut BufWriter<File>,
            encoding: Encoding,
            xs: &[f32],
            max_err: &mut f32,
            n_comp: &mut u64,
        ) -> io::Result<()> {
            match encoding {
                Encoding::F32 => {
                    for x in xs {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
                Encoding::F16 => {
                    for x in xs {
                        let bits = f32_to_f16_bits(*x);
                        let err = (x - f16_bits_to_f32(bits)).abs();
                        if err > *max_err {
                            *max_err = err;
                        }
                        *n_comp += 1;
                        w.write_all(&bits.to_le_bytes())?;
                    }
                }
            }
            Ok(())
        }

        self.writer.write_all(&RECORD_MAGIC.to_le_bytes())?;
        self.writer.write_all(&(cp.step as u64).to_le_bytes())?;
        self.writer.write_all(&cp.t.to_le_bytes())?;
        self.writer.write_all(&cp.h.to_le_bytes())?;
        self.writer.write_all(&meta.u_len.to_le_bytes())?;
        self.writer.write_all(&meta.n_stages.to_le_bytes())?;
        self.writer.write_all(&meta.stage_len.to_le_bytes())?;
        self.writer.write_all(&[encoding.tag(), 0, 0, 0])?;

        let mut max_err = self.compress_max_abs_err;
        let mut n_comp = self.compressed_elems;
        write_slice(&mut self.writer, encoding, &cp.u, &mut max_err, &mut n_comp)?;
        if let Some(ks) = &cp.ks {
            for k in ks {
                write_slice(&mut self.writer, encoding, k, &mut max_err, &mut n_comp)?;
            }
        }
        self.compress_max_abs_err = max_err;
        self.compressed_elems = n_comp;

        let total = meta.total_bytes();
        self.write_offset += total;
        self.bytes_written += total;
        self.spills += 1;
        self.writer_dirty = true;
        if let Some(old) = self.index.insert(cp.step, meta) {
            self.live_bytes -= old.total_bytes();
        }
        self.live_bytes += total;
        Ok(())
    }

    /// Make pending writes visible to `self.reader` and other handles on
    /// the file (the prefetcher's).
    pub fn flush(&mut self) -> io::Result<()> {
        if self.writer_dirty {
            self.writer.flush()?;
            self.writer_dirty = false;
        }
        Ok(())
    }

    /// Read the record for `step` back into RAM (the index entry stays —
    /// pair with [`ColdStore::remove`] to consume it).
    pub fn read(&mut self, step: usize) -> io::Result<Option<StepCheckpoint>> {
        let meta = match self.index.get(&step) {
            Some(m) => *m,
            None => return Ok(None),
        };
        self.flush()?;
        read_record(&mut self.reader, &meta).map(Some)
    }

    /// Drop the index entry for `step`.  Returns whether it existed.
    pub fn remove(&mut self, step: usize) -> bool {
        match self.index.remove(&step) {
            Some(meta) => {
                self.live_bytes -= meta.total_bytes();
                true
            }
            None => false,
        }
    }

    pub fn clear(&mut self) {
        self.index.clear();
        self.live_bytes = 0;
        self.bytes_written = 0;
        self.spills = 0;
        self.compressed_elems = 0;
        self.compress_max_abs_err = 0.0;
        // leave the file as-is; write_offset keeps growing (offsets must
        // stay unique), the file dies with the store
    }
}

impl Drop for ColdStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Decode one record at `meta.offset` from `file`.  Shared by the store's
/// synchronous path and the prefetcher thread (which holds its own handle).
pub fn read_record(file: &mut File, meta: &RecordMeta) -> io::Result<StepCheckpoint> {
    file.seek(SeekFrom::Start(meta.offset))?;
    let mut header = [0u8; HEADER_BYTES as usize];
    file.read_exact(&mut header)?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap()); // lint:allow(panic): a 4-byte slice always converts to [u8; 4]
    let step = u64::from_le_bytes(header[4..12].try_into().unwrap()) as usize; // lint:allow(panic): an 8-byte slice always converts to [u8; 8]
    let enc_tag = header[40];
    if magic != RECORD_MAGIC || step != meta.step || Encoding::from_tag(enc_tag) != Some(meta.encoding)
    {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("corrupt spill record at offset {} (step {})", meta.offset, meta.step),
        ));
    }
    let mut payload = vec![0u8; meta.payload_bytes() as usize];
    file.read_exact(&mut payload)?;

    let decode = |bytes: &[u8]| -> Vec<f32> {
        match meta.encoding {
            Encoding::F32 => bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap())) // lint:allow(panic): chunks_exact(4) yields exactly-4-byte chunks
                .collect(),
            Encoding::F16 => bytes
                .chunks_exact(2)
                .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap()))) // lint:allow(panic): chunks_exact(2) yields exactly-2-byte chunks
                .collect(),
        }
    };
    let eb = meta.encoding.elem_bytes() as usize;
    let u_bytes = meta.u_len as usize * eb;
    let u = decode(&payload[..u_bytes]);
    let ks = if meta.n_stages > 0 {
        let stage_bytes = meta.stage_len as usize * eb;
        let mut ks = Vec::with_capacity(meta.n_stages as usize);
        for i in 0..meta.n_stages as usize {
            let lo = u_bytes + i * stage_bytes;
            ks.push(decode(&payload[lo..lo + stage_bytes]));
        }
        Some(ks)
    } else {
        None
    };
    Ok(StepCheckpoint { step: meta.step, t: meta.t, h: meta.h, u, ks })
}

// ---------------------------------------------------------------------------
// f16 codec (IEEE 754 binary16, round-to-nearest-even) — hand-rolled, the
// offline registry has no `half` crate.
// ---------------------------------------------------------------------------

/// Convert an f32 to binary16 bits with round-to-nearest-even.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp32 = ((x >> 23) & 0xff) as i32;
    let mant = x & 0x007f_ffff;
    if exp32 == 255 {
        // Inf / NaN (quiet any NaN payload into a canonical one)
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let exp = exp32 - 127 + 15;
    if exp >= 31 {
        return sign | 0x7c00; // overflow -> ±Inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflow -> ±0
        }
        // subnormal: shift the (implicit-bit) mantissa into 10 bits
        let m = mant | 0x0080_0000;
        let shift = (14 - exp) as u32; // in [14, 24]
        let half_mant = (m >> shift) as u16;
        let round_bit = 1u32 << (shift - 1);
        // round up when the round bit is set and (sticky || result-lsb)
        if (m & round_bit) != 0 && (m & (3 * round_bit - 1)) != 0 {
            return sign | (half_mant + 1);
        }
        return sign | half_mant;
    }
    let half = (sign as u32) | ((exp as u32) << 10) | (mant >> 13);
    let round_bit = 0x0000_1000u32; // dropped bit 12
    if (mant & round_bit) != 0 && (mant & ((round_bit << 1) | (round_bit - 1))) != 0 {
        // carry may ripple into the exponent; that is the correct result
        // (e.g. rounding up to the next power of two, or to Inf)
        return (half + 1) as u16;
    }
    half as u16
}

/// Convert binary16 bits back to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: renormalize
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x03ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("pnode-cold-test-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn cp(step: usize, n: usize, stages: usize, seed: u64) -> StepCheckpoint {
        let mut rng = Rng::new(seed);
        let mut u = vec![0.0f32; n];
        rng.fill_normal(&mut u);
        let ks = (stages > 0).then(|| {
            (0..stages)
                .map(|_| {
                    let mut k = vec![0.0f32; n];
                    rng.fill_normal(&mut k);
                    k
                })
                .collect()
        });
        StepCheckpoint { step, t: 0.25 * step as f64, h: 0.25, u, ks }
    }

    #[test]
    fn f16_codec_known_values() {
        for (f, bits) in [
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (65504.0, 0x7bff),  // f16 max
            (6.1035156e-5, 0x0400), // smallest normal
            (5.9604645e-8, 0x0001), // smallest subnormal
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
        ] {
            assert_eq!(f32_to_f16_bits(f), bits, "{f}");
            if f.is_finite() {
                assert_eq!(f16_bits_to_f32(bits), f, "{bits:#x}");
            }
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // overflow saturates to Inf
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e9), 0xfc00);
        // underflow flushes to zero
        assert_eq!(f32_to_f16_bits(1e-10), 0x0000);
    }

    #[test]
    fn f16_roundtrip_error_is_bounded() {
        let mut rng = Rng::new(99);
        let mut xs = vec![0.0f32; 4096];
        rng.fill_normal(&mut xs);
        for x in xs {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            // f16 has 11 significand bits: relative error <= 2^-11
            assert!((x - y).abs() <= x.abs() * 4.9e-4 + 6e-8, "{x} -> {y}");
        }
    }

    #[test]
    fn f16_roundtrip_is_idempotent() {
        let mut rng = Rng::new(7);
        let mut xs = vec![0.0f32; 512];
        rng.fill_normal(&mut xs);
        for x in xs {
            let bits = f32_to_f16_bits(x);
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(bits)), bits);
        }
    }

    #[test]
    fn cold_store_roundtrip_lossless() {
        let dir = tmp_dir("lossless");
        let mut cold = ColdStore::create(&dir, false).unwrap();
        let cps: Vec<StepCheckpoint> =
            (0..5).map(|s| cp(s, 37, if s % 2 == 0 { 4 } else { 0 }, s as u64)).collect();
        for c in &cps {
            cold.append(c).unwrap();
        }
        assert_eq!(cold.len(), 5);
        assert_eq!(cold.spills, 5);
        assert!(cold.live_bytes > 0);
        assert_eq!(cold.compressed_elems, 0);
        for c in cps.iter().rev() {
            let back = cold.read(c.step).unwrap().unwrap();
            assert_eq!(back.step, c.step);
            assert_eq!(back.t, c.t);
            assert_eq!(back.h, c.h);
            assert_eq!(back.u, c.u, "u bitwise");
            assert_eq!(back.ks, c.ks, "stages bitwise");
            assert!(cold.remove(c.step));
        }
        assert!(cold.is_empty());
        assert_eq!(cold.live_bytes, 0);
        let path = cold.path().to_path_buf();
        assert!(path.exists());
        drop(cold);
        assert!(!path.exists(), "spill file deleted on drop");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cold_store_f16_accounts_error() {
        let dir = tmp_dir("f16");
        let mut cold = ColdStore::create(&dir, true).unwrap();
        let c = cp(3, 64, 2, 11);
        cold.append(&c).unwrap();
        assert_eq!(cold.compressed_elems, (64 * 3) as u64);
        let back = cold.read(3).unwrap().unwrap();
        let mut worst = 0.0f32;
        for (a, b) in c.u.iter().zip(&back.u) {
            worst = worst.max((a - b).abs());
        }
        assert!(worst <= cold.compress_max_abs_err);
        // payload is half the f32 size
        let meta = cold.snapshot_desc()[0];
        assert_eq!(meta.payload_bytes(), (64 * 3 * 2) as u64);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replacing_a_step_keeps_live_bytes_consistent() {
        let dir = tmp_dir("replace");
        let mut cold = ColdStore::create(&dir, false).unwrap();
        cold.append(&cp(4, 16, 0, 1)).unwrap();
        let live1 = cold.live_bytes;
        cold.append(&cp(4, 16, 2, 2)).unwrap();
        assert_eq!(cold.len(), 1);
        assert!(cold.live_bytes > live1);
        let back = cold.read(4).unwrap().unwrap();
        assert_eq!(back.ks.as_ref().map(|k| k.len()), Some(2), "newest version wins");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_is_descending() {
        let dir = tmp_dir("desc");
        let mut cold = ColdStore::create(&dir, false).unwrap();
        for s in [2usize, 9, 5, 0] {
            cold.append(&cp(s, 8, 0, s as u64)).unwrap();
        }
        let steps: Vec<usize> = cold.snapshot_desc().iter().map(|m| m.step).collect();
        assert_eq!(steps, vec![9, 5, 2, 0]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
