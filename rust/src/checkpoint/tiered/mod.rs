//! Tiered checkpoint storage (DESIGN.md §6).
//!
//! The adjoint drivers talk to checkpoint storage through the
//! [`CheckpointBackend`] trait.  Two backends exist:
//!
//! * the in-RAM [`crate::checkpoint::CheckpointStore`] (the `InMemory`
//!   backend — everything resident, exact byte accounting), and
//! * [`TieredStore`]: a [`MemoryBudget`]-governed hot tier that evicts
//!   least-soon-needed step checkpoints to a file-backed cold tier
//!   ([`ColdStore`], compact binary records, optional f16 compression with
//!   error accounting), plus a background [`Prefetcher`] that streams cold
//!   records back in reverse step order during the adjoint sweep so disk
//!   reads overlap stage recomputation.
//!
//! "Least-soon-needed" exploits the adjoint access pattern: the backward
//! sweep consumes checkpoints from step `N_t - 1` down to `0`, so the
//! smallest resident step index is always the one needed furthest in the
//! future — eviction is a single `BTreeMap` front-pop, no clairvoyance
//! required (this is the Belady-optimal victim for the reverse sweep).

pub mod budget;
pub mod cold;
pub mod prefetch;
pub mod store;

pub use budget::MemoryBudget;
pub use cold::{f16_bits_to_f32, f32_to_f16_bits, ColdStore, Encoding};
pub use prefetch::Prefetcher;
pub use store::{TieredConfig, TieredStore};

use crate::checkpoint::store::{CheckpointStore, StepCheckpoint};

/// Counters a storage backend reports after a forward+backward pass.
/// All-zero (except the hot fields) for the in-memory backend.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TierStats {
    /// bytes currently resident in the hot (RAM) tier
    pub hot_bytes: u64,
    /// peak bytes ever resident in the hot tier
    pub peak_hot_bytes: u64,
    /// total bytes appended to the cold (disk) tier
    pub cold_bytes_written: u64,
    /// bytes of live (not yet consumed) cold records
    pub cold_bytes_live: u64,
    /// number of checkpoints evicted hot → cold
    pub spills: u64,
    /// lookups served from RAM without touching the cold tier
    pub hot_hits: u64,
    /// cold lookups satisfied by the background prefetcher
    pub prefetch_hits: u64,
    /// cold lookups that had to read the file synchronously
    pub cold_reads: u64,
    /// elements stored through the f16 codec
    pub compressed_elems: u64,
    /// max |x - decode(encode(x))| introduced by f16 compression
    pub compress_max_abs_err: f32,
}

/// Step-indexed checkpoint storage as seen by the adjoint drivers.
///
/// Lookups take `&mut self` because a tiered backend may migrate a record
/// from disk into RAM to satisfy them.  `Send` so runs can move across
/// worker threads (the coordinator's thread-pool path, future sharding).
pub trait CheckpointBackend: Send {
    /// Store a checkpoint (replacing any previous one at the same step).
    fn insert(&mut self, cp: StepCheckpoint);

    /// Remove and return the checkpoint at `step`, from whichever tier
    /// holds it.
    fn take(&mut self, step: usize) -> Option<StepCheckpoint>;

    /// Borrow the checkpoint at `step`, promoting it to the hot tier
    /// first if it lives on disk.
    fn get(&mut self, step: usize) -> Option<&StepCheckpoint>;

    /// Whether any tier holds a checkpoint for `step` (no I/O).
    fn contains(&self, step: usize) -> bool;

    /// Number of live checkpoints across all tiers.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently resident in RAM.
    fn hot_bytes(&self) -> u64;

    /// Peak bytes ever resident in RAM.
    fn peak_hot_bytes(&self) -> u64;

    /// Drop every checkpoint (all tiers) and stop any background work.
    fn clear(&mut self);

    /// Called when the backward sweep starts: the access pattern from here
    /// on is (mostly) descending step order.  Tiered backends launch the
    /// reverse-order prefetcher here.
    fn begin_reverse_sweep(&mut self) {}

    /// Called after the backward sweep: join background threads, settle
    /// counters.
    fn finish(&mut self) {}

    /// Tier counters for reporting (zeros where not applicable).
    fn stats(&self) -> TierStats;
}

impl CheckpointBackend for CheckpointStore {
    fn insert(&mut self, cp: StepCheckpoint) {
        CheckpointStore::insert(self, cp);
    }

    fn take(&mut self, step: usize) -> Option<StepCheckpoint> {
        CheckpointStore::remove(self, step)
    }

    fn get(&mut self, step: usize) -> Option<&StepCheckpoint> {
        CheckpointStore::get(self, step)
    }

    fn contains(&self, step: usize) -> bool {
        CheckpointStore::get(self, step).is_some()
    }

    fn len(&self) -> usize {
        CheckpointStore::len(self)
    }

    fn hot_bytes(&self) -> u64 {
        self.bytes()
    }

    fn peak_hot_bytes(&self) -> u64 {
        self.peak_bytes()
    }

    fn clear(&mut self) {
        CheckpointStore::clear(self);
    }

    fn stats(&self) -> TierStats {
        TierStats {
            hot_bytes: self.bytes(),
            peak_hot_bytes: self.peak_bytes(),
            ..TierStats::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cp(step: usize, n: usize) -> StepCheckpoint {
        StepCheckpoint { step, t: step as f64, h: 1.0, u: vec![1.0; n], ks: None }
    }

    #[test]
    fn in_memory_backend_roundtrip_through_trait() {
        let mut store: Box<dyn CheckpointBackend> = Box::new(CheckpointStore::new());
        store.insert(cp(3, 8));
        store.insert(cp(7, 8));
        assert_eq!(store.len(), 2);
        assert!(store.contains(3) && !store.contains(4));
        assert_eq!(store.get(7).unwrap().step, 7);
        let taken = store.take(3).unwrap();
        assert_eq!(taken.step, 3);
        assert_eq!(store.len(), 1);
        assert!(store.stats().peak_hot_bytes > 0);
        assert_eq!(store.stats().spills, 0);
        store.clear();
        assert!(store.is_empty());
    }
}
