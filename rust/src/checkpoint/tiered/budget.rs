//! RAM budget for the hot checkpoint tier.

/// A byte budget with a human-friendly parser (`"4096"`, `"64k"`, `"8m"`,
/// `"2g"`; binary multiples).  `u64::MAX` means unlimited.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemoryBudget {
    pub bytes: u64,
}

impl MemoryBudget {
    pub fn unlimited() -> MemoryBudget {
        MemoryBudget { bytes: u64::MAX }
    }

    pub fn from_bytes(bytes: u64) -> MemoryBudget {
        MemoryBudget { bytes }
    }

    pub fn is_unlimited(&self) -> bool {
        self.bytes == u64::MAX
    }

    /// Parse `"<number>[k|m|g]"` (case-insensitive).  Zero budgets are
    /// rejected: a hot tier that can hold nothing deadlocks the sweep.
    pub fn parse(s: &str) -> Result<MemoryBudget, String> {
        let t = s.trim().to_ascii_lowercase();
        if t.is_empty() {
            return Err("empty memory budget".into());
        }
        let (num, mult) = match t.as_bytes()[t.len() - 1] {
            b'k' => (&t[..t.len() - 1], 1u64 << 10),
            b'm' => (&t[..t.len() - 1], 1u64 << 20),
            b'g' => (&t[..t.len() - 1], 1u64 << 30),
            _ => (t.as_str(), 1u64),
        };
        let n: u64 = num
            .parse()
            .map_err(|_| format!("bad memory budget {s:?} (want e.g. \"4096\", \"64k\", \"8m\")"))?;
        let bytes = n
            .checked_mul(mult)
            .ok_or_else(|| format!("memory budget {s:?} overflows u64"))?;
        if bytes == 0 {
            return Err(format!("memory budget {s:?} is zero; the hot tier needs room for at least one checkpoint"));
        }
        Ok(MemoryBudget { bytes })
    }

    /// Render in the same grammar `parse` accepts (exact round-trip).
    pub fn display(&self) -> String {
        let b = self.bytes;
        for (shift, suffix) in [(30u32, "g"), (20, "m"), (10, "k")] {
            if b >= (1 << shift) && b % (1 << shift) == 0 {
                return format!("{}{suffix}", b >> shift);
            }
        }
        b.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_suffixes() {
        assert_eq!(MemoryBudget::parse("4096").unwrap().bytes, 4096);
        assert_eq!(MemoryBudget::parse("64k").unwrap().bytes, 64 << 10);
        assert_eq!(MemoryBudget::parse("8M").unwrap().bytes, 8 << 20);
        assert_eq!(MemoryBudget::parse("2g").unwrap().bytes, 2u64 << 30);
        assert!(MemoryBudget::parse("0").is_err());
        assert!(MemoryBudget::parse("0m").is_err());
        assert!(MemoryBudget::parse("").is_err());
        assert!(MemoryBudget::parse("12q").is_err());
        assert!(MemoryBudget::parse("-5").is_err());
    }

    #[test]
    fn display_round_trips() {
        for s in ["4096", "64k", "8m", "2g", "1023", "3145728"] {
            let b = MemoryBudget::parse(s).unwrap();
            assert_eq!(MemoryBudget::parse(&b.display()).unwrap(), b, "{s}");
        }
        assert_eq!(MemoryBudget::parse("8m").unwrap().display(), "8m");
    }
}
