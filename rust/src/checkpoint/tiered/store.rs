//! The tiered backend: budgeted hot tier over a spill file, with
//! reverse-order prefetch during the adjoint sweep.

use std::collections::BTreeMap;
use std::io;
use std::path::PathBuf;
use std::sync::Arc;

use super::budget::MemoryBudget;
use super::cold::ColdStore;
use super::prefetch::Prefetcher;
use super::{CheckpointBackend, TierStats};
use crate::checkpoint::store::StepCheckpoint;
use crate::exec::arbiter::{BudgetArbiter, Lease};
use crate::obs;

/// Construction parameters for [`TieredStore`].
#[derive(Clone, Debug)]
pub struct TieredConfig {
    /// RAM allowance for the hot tier (prefetch buffer included).  When
    /// `arbiter` is set this is the *global* pool size for display; the
    /// store's actual allowance is whatever its lease covers.
    pub budget: MemoryBudget,
    /// directory for the spill file (created if absent, file deleted on drop)
    pub dir: PathBuf,
    /// store cold payloads as f16 (2× smaller, lossy, error-accounted)
    pub compress_f16: bool,
    /// prefetch read-ahead window, in records
    pub prefetch_window: usize,
    /// shared checkpoint-memory arbiter: when set, the hot tier draws its
    /// allowance from the arbiter's global pool (fleet mode) instead of
    /// the fixed per-store `budget`
    pub arbiter: Option<Arc<BudgetArbiter>>,
}

impl TieredConfig {
    pub fn new(budget_bytes: u64, dir: impl Into<PathBuf>) -> TieredConfig {
        TieredConfig {
            budget: MemoryBudget::from_bytes(budget_bytes),
            dir: dir.into(),
            compress_f16: false,
            prefetch_window: 4,
            arbiter: None,
        }
    }
}

/// Two-tier checkpoint store.
///
/// Invariants: a step lives in exactly one place — `hot`, `prefetched`
/// (+ its `cold` index entry, which is dropped on consumption), or `cold`.
/// `hot_bytes + prefetched_bytes` is the RAM footprint and is what the
/// budget governs.
pub struct TieredStore {
    hot: BTreeMap<usize, StepCheckpoint>,
    hot_bytes: u64,
    peak_hot_bytes: u64,
    budget: MemoryBudget,
    /// fleet mode: the allowance comes from this lease on the shared
    /// arbiter pool rather than from the fixed `budget`
    lease: Option<Lease>,
    cold: ColdStore,
    /// prefetched-but-not-yet-consumed records (step -> checkpoint)
    prefetched: BTreeMap<usize, StepCheckpoint>,
    prefetched_bytes: u64,
    prefetcher: Option<Prefetcher>,
    prefetch_window: usize,
    stats_hot_hits: u64,
    stats_prefetch_hits: u64,
    stats_cold_reads: u64,
}

impl TieredStore {
    pub fn create(cfg: TieredConfig) -> io::Result<TieredStore> {
        let cold = ColdStore::create(&cfg.dir, cfg.compress_f16)?;
        Ok(TieredStore {
            hot: BTreeMap::new(),
            hot_bytes: 0,
            peak_hot_bytes: 0,
            budget: cfg.budget,
            lease: cfg.arbiter.as_ref().map(|a| a.lease()),
            cold,
            prefetched: BTreeMap::new(),
            prefetched_bytes: 0,
            prefetcher: None,
            prefetch_window: cfg.prefetch_window.max(1),
            stats_hot_hits: 0,
            stats_prefetch_hits: 0,
            stats_cold_reads: 0,
        })
    }

    fn ram_bytes(&self) -> u64 {
        self.hot_bytes + self.prefetched_bytes
    }

    fn note_peak(&mut self) {
        self.peak_hot_bytes = self.peak_hot_bytes.max(self.ram_bytes());
    }

    /// The RAM this store may use right now: its lease's coverage in
    /// fleet mode, the fixed budget otherwise.  Passive — growing the
    /// allowance goes through `ask`/`settle` on the lease.
    fn allowance(&self) -> u64 {
        match &self.lease {
            Some(l) => l.held(),
            None => self.budget.bytes,
        }
    }

    /// Record the actual RAM footprint with the arbiter (release on
    /// shrink; mandatory floor — counted, never refused — when eviction
    /// cannot get below one resident record).
    fn sync_lease(&mut self) {
        let now = self.ram_bytes();
        if let Some(l) = &mut self.lease {
            l.settle(now);
        }
    }

    /// Evict least-soon-needed (smallest-step) hot entries until the RAM
    /// footprint fits the allowance (asking the arbiter for coverage
    /// first in fleet mode).  `protect` is never evicted and at least
    /// one entry always stays resident (spilling the sole checkpoint just
    /// to re-read it immediately would thrash).
    fn enforce_budget(&mut self, protect: Option<usize>) {
        let want = self.ram_bytes();
        let allowed = match &mut self.lease {
            Some(l) => l.ask(want),
            None => self.budget.bytes,
        };
        while self.ram_bytes() > allowed && self.hot.len() > 1 {
            let victim = match self.hot.keys().copied().find(|s| Some(*s) != protect) {
                Some(v) => v,
                None => break,
            };
            // lint:allow(panic): the LRU scan above only yields keys resident in the hot map
            let cp = self.hot.remove(&victim).expect("victim resident");
            self.hot_bytes -= cp.bytes();
            let _sp = obs::span("tier.spill");
            self.cold
                .append(&cp)
                // lint:allow(panic): a failed spill (disk full / spill dir removed) loses checkpoint data; no recovery mid-sweep
                .expect("checkpoint spill failed (disk full or spill dir gone?)");
        }
        self.sync_lease();
        if obs::enabled() {
            obs::gauge("tier.hot_bytes", self.ram_bytes() as f64);
        }
    }

    fn hot_insert(&mut self, cp: StepCheckpoint, protect: Option<usize>) {
        let step = cp.step;
        let add = cp.bytes();
        if let Some(old) = self.hot.insert(step, cp) {
            self.hot_bytes -= old.bytes();
        }
        self.hot_bytes += add;
        // a fresh insert supersedes any older tier copy of the same step —
        // including one still in flight from the prefetcher (its payload
        // is the stale version; mark it so it gets dropped on arrival)
        self.cold.remove(step);
        if let Some(old) = self.prefetched.remove(&step) {
            self.prefetched_bytes -= old.bytes();
        }
        if let Some(pf) = &mut self.prefetcher {
            pf.invalidate(step);
        }
        self.note_peak();
        self.enforce_budget(protect);
    }

    /// Whether a record of `incoming` bytes may be buffered in RAM right
    /// now.  Fleet mode asks the arbiter to extend the lease first, so
    /// prefetch buffering also draws from the global pool.
    fn can_buffer(&mut self, incoming: u64) -> bool {
        let want = self.ram_bytes() + incoming;
        match &mut self.lease {
            Some(l) => l.ask(want) >= want,
            None => want <= self.budget.bytes,
        }
    }

    /// Drain whatever the prefetcher has ready, respecting the allowance
    /// (entries left in the channel keep back-pressuring the reader
    /// thread).  Records whose index entry vanished (consumed through
    /// another path) are dropped; in fleet mode a record the pool cannot
    /// cover is dropped too (its cold entry remains — a later lookup
    /// falls back to a synchronous read) so the fleet never overdraws.
    fn drain_prefetch(&mut self) {
        loop {
            if self.ram_bytes() >= self.allowance() && !self.prefetched.is_empty() {
                break;
            }
            let cp = match self.prefetcher.as_mut().and_then(|pf| pf.try_recv()) {
                Some(cp) => cp,
                None => break,
            };
            if !self.cold.contains(cp.step) {
                continue;
            }
            if self.lease.is_some() && !self.can_buffer(cp.bytes()) {
                break; // drop cp: pool exhausted
            }
            self.prefetched_bytes += cp.bytes();
            self.prefetched.insert(cp.step, cp);
            self.note_peak();
        }
        self.sync_lease();
    }

    /// Pull `step` out of the cold tier (prefetched buffer, in-flight
    /// prefetch, or synchronous read), removing its cold index entry.
    fn fetch_cold(&mut self, step: usize) -> Option<StepCheckpoint> {
        if !self.cold.contains(step) {
            return None;
        }
        self.drain_prefetch();
        if let Some(cp) = self.prefetched.remove(&step) {
            self.prefetched_bytes -= cp.bytes();
            self.cold.remove(step);
            self.stats_prefetch_hits += 1;
            self.sync_lease();
            return Some(cp);
        }
        // If the record is still ahead in the prefetch stream, wait for it:
        // the read is already in flight, a second synchronous read would
        // double the I/O.  Records received on the way down are kept only
        // while they fit the budget — beyond that they are dropped (their
        // cold entries remain, a later lookup re-reads them), so RAM stays
        // bounded by budget + one record even under out-of-order access.
        if self.prefetcher.as_ref().map(|pf| pf.will_deliver(step)).unwrap_or(false) {
            let _sp = obs::span("tier.prefetch_wait");
            while let Some(cp) = self.prefetcher.as_mut().and_then(|pf| pf.recv()) {
                if cp.step == step {
                    self.cold.remove(step);
                    self.stats_prefetch_hits += 1;
                    self.sync_lease();
                    return Some(cp);
                }
                if self.cold.contains(cp.step) && self.can_buffer(cp.bytes()) {
                    self.prefetched_bytes += cp.bytes();
                    self.prefetched.insert(cp.step, cp);
                    self.note_peak();
                }
            }
        }
        // prefetcher gone or out of order: synchronous read.  Invalidate
        // any still-in-flight delivery of this step — if the step is later
        // re-spilled, that old payload must not satisfy the new entry.
        let cp = {
            let _sp = obs::span("tier.cold_read");
            self.cold
                .read(step)
                // lint:allow(panic): an unreadable spill file mid-backward is unrecoverable
                .expect("cold tier read failed")
                // lint:allow(panic): records indexed in the cold map were fully written by append
                .expect("indexed record readable")
        };
        self.cold.remove(step);
        if let Some(pf) = &mut self.prefetcher {
            pf.invalidate(step);
        }
        self.stats_cold_reads += 1;
        self.sync_lease();
        Some(cp)
    }

    fn stop_prefetcher(&mut self) {
        self.prefetcher = None; // Drop disconnects the channel and joins
    }
}

impl CheckpointBackend for TieredStore {
    fn insert(&mut self, cp: StepCheckpoint) {
        let step = cp.step;
        self.hot_insert(cp, Some(step));
    }

    fn take(&mut self, step: usize) -> Option<StepCheckpoint> {
        if let Some(cp) = self.hot.remove(&step) {
            self.hot_bytes -= cp.bytes();
            self.stats_hot_hits += 1;
            self.sync_lease();
            if obs::enabled() {
                obs::gauge("tier.hot_bytes", self.ram_bytes() as f64);
            }
            return Some(cp);
        }
        self.fetch_cold(step)
    }

    fn get(&mut self, step: usize) -> Option<&StepCheckpoint> {
        if self.hot.contains_key(&step) {
            self.stats_hot_hits += 1;
        } else {
            let cp = self.fetch_cold(step)?;
            self.hot_insert(cp, Some(step));
        }
        self.hot.get(&step)
    }

    fn contains(&self, step: usize) -> bool {
        self.hot.contains_key(&step)
            || self.prefetched.contains_key(&step)
            || self.cold.contains(step)
    }

    fn len(&self) -> usize {
        // prefetched records still hold their cold index entry, so hot +
        // cold covers everything exactly once
        self.hot.len() + self.cold.len()
    }

    fn hot_bytes(&self) -> u64 {
        self.ram_bytes()
    }

    fn peak_hot_bytes(&self) -> u64 {
        self.peak_hot_bytes
    }

    fn clear(&mut self) {
        // a cleared store starts a fresh run: counters and peaks reset so
        // reused runs (AdjointDriver::forward calls clear first) report
        // per-run numbers, not lifetime totals
        self.stop_prefetcher();
        self.hot.clear();
        self.hot_bytes = 0;
        self.peak_hot_bytes = 0;
        self.prefetched.clear();
        self.prefetched_bytes = 0;
        self.stats_hot_hits = 0;
        self.stats_prefetch_hits = 0;
        self.stats_cold_reads = 0;
        self.cold.clear();
        self.sync_lease();
    }

    fn begin_reverse_sweep(&mut self) {
        self.stop_prefetcher();
        if self.cold.is_empty() {
            return;
        }
        if self.cold.flush().is_err() {
            return; // fall back to per-record synchronous reads
        }
        self.prefetcher =
            Prefetcher::spawn(self.cold.path(), self.cold.snapshot_desc(), self.prefetch_window)
                .ok();
    }

    fn finish(&mut self) {
        self.stop_prefetcher();
    }

    fn stats(&self) -> TierStats {
        TierStats {
            hot_bytes: self.ram_bytes(),
            peak_hot_bytes: self.peak_hot_bytes,
            cold_bytes_written: self.cold.bytes_written,
            cold_bytes_live: self.cold.live_bytes,
            spills: self.cold.spills,
            hot_hits: self.stats_hot_hits,
            prefetch_hits: self.stats_prefetch_hits,
            cold_reads: self.stats_cold_reads,
            compressed_elems: self.cold.compressed_elems,
            compress_max_abs_err: self.cold.compress_max_abs_err,
        }
    }
}

impl Drop for TieredStore {
    fn drop(&mut self) {
        self.stop_prefetcher();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("pnode-tiered-test-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn cp(step: usize, n: usize, stages: usize, seed: u64) -> StepCheckpoint {
        let mut rng = Rng::new(seed);
        let mut u = vec![0.0f32; n];
        rng.fill_normal(&mut u);
        let ks = if stages > 0 {
            let mut ks = Vec::new();
            for _ in 0..stages {
                let mut k = vec![0.0f32; n];
                rng.fill_normal(&mut k);
                ks.push(k);
            }
            Some(ks)
        } else {
            None
        };
        StepCheckpoint { step, t: step as f64, h: 1.0, u, ks }
    }

    fn mk(budget: u64, tag: &str) -> (TieredStore, PathBuf) {
        let dir = tmp_dir(tag);
        let store = TieredStore::create(TieredConfig::new(budget, &dir)).unwrap();
        (store, dir)
    }

    #[test]
    fn spills_beyond_budget_and_reads_back_bitwise() {
        // each checkpoint: 64 floats * (1+2 stages) * 4B + 48 = 816 B
        let per = cp(0, 64, 2, 0).bytes();
        let (mut store, dir) = mk(3 * per, "spill");
        let originals: Vec<StepCheckpoint> = (0..10).map(|s| cp(s, 64, 2, s as u64)).collect();
        for c in &originals {
            store.insert(c.clone());
        }
        let st = store.stats();
        assert!(st.hot_bytes <= 3 * per, "hot tier fits budget: {} <= {}", st.hot_bytes, 3 * per);
        assert_eq!(st.spills, 7, "10 inserted, 3 resident");
        assert_eq!(store.len(), 10, "nothing lost");
        // the *largest* steps stay hot (they are needed first in reverse)
        assert!(store.hot.contains_key(&9) && store.hot.contains_key(&8));
        assert!(store.cold.contains(0));

        store.begin_reverse_sweep();
        for c in originals.iter().rev() {
            let back = store.take(c.step).expect("present");
            assert_eq!(back.u, c.u, "step {} u bitwise", c.step);
            assert_eq!(back.ks, c.ks, "step {} stages bitwise", c.step);
        }
        store.finish();
        let st = store.stats();
        assert_eq!(st.hot_hits + st.prefetch_hits + st.cold_reads, 10);
        assert_eq!(st.hot_hits, 3);
        assert!(
            st.prefetch_hits >= 1,
            "reverse sweep must hit the prefetcher: {st:?}"
        );
        assert!(store.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reverse_sweep_with_prefetch_hits_everything() {
        let per = cp(0, 32, 0, 0).bytes();
        let (mut store, dir) = mk(2 * per, "allhits");
        for s in 0..20 {
            store.insert(cp(s, 32, 0, s as u64));
        }
        store.begin_reverse_sweep();
        for s in (0..20).rev() {
            assert!(store.take(s).is_some(), "step {s}");
        }
        store.finish();
        let st = store.stats();
        // delivery order == consumption order, so no synchronous reads
        assert_eq!(st.cold_reads, 0, "prefetcher should satisfy all cold lookups: {st:?}");
        assert_eq!(st.prefetch_hits, 18);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn get_promotes_to_hot_without_losing_the_record() {
        let per = cp(0, 16, 0, 0).bytes();
        let (mut store, dir) = mk(2 * per, "promote");
        for s in 0..6 {
            store.insert(cp(s, 16, 0, s as u64));
        }
        assert!(store.cold.contains(1));
        let u_before = store.get(1).expect("promoted").u.clone();
        assert!(store.hot.contains_key(&1), "resident after get");
        assert!(!store.cold.contains(1), "single owner");
        assert_eq!(store.take(1).unwrap().u, u_before);
        assert_eq!(store.len(), 5);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn out_of_order_access_falls_back_to_sync_reads() {
        let per = cp(0, 16, 0, 0).bytes();
        let (mut store, dir) = mk(per, "ooo");
        for s in 0..8 {
            store.insert(cp(s, 16, 0, s as u64));
        }
        store.begin_reverse_sweep();
        // ascending (wrong-direction) access: steps below the prefetch
        // front are still in flight -> prefetch; consumed fronts are fine
        for s in 0..8 {
            assert!(store.take(s).is_some(), "step {s}");
        }
        store.finish();
        let st = store.stats();
        assert_eq!(st.hot_hits + st.prefetch_hits + st.cold_reads, 8);
        assert!(store.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unlimited_budget_never_spills() {
        let (mut store, dir) = mk(u64::MAX, "unlim");
        for s in 0..12 {
            store.insert(cp(s, 8, 1, s as u64));
        }
        assert_eq!(store.stats().spills, 0);
        assert_eq!(store.hot.len(), 12);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clear_starts_a_fresh_run_with_fresh_counters() {
        let per = cp(0, 16, 0, 0).bytes();
        let (mut store, dir) = mk(2 * per, "clearstats");
        for s in 0..6 {
            store.insert(cp(s, 16, 0, s as u64));
        }
        store.begin_reverse_sweep();
        for s in (0..6).rev() {
            let _ = store.take(s);
        }
        store.finish();
        let st1 = store.stats();
        assert!(st1.spills > 0 && st1.peak_hot_bytes > 0);
        store.clear();
        let st2 = store.stats();
        assert_eq!(st2.spills, 0, "per-run counters reset: {st2:?}");
        assert_eq!(st2.peak_hot_bytes, 0);
        assert_eq!(st2.cold_bytes_written, 0);
        assert_eq!(st2.hot_hits + st2.prefetch_hits + st2.cold_reads, 0);
        // the second run accounts independently
        for s in 0..4 {
            store.insert(cp(s, 16, 0, s as u64));
        }
        assert_eq!(store.stats().spills, 2);
        assert_eq!(store.len(), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn superseding_a_spilled_step_mid_sweep_returns_the_new_version() {
        // regression: a step spilled before the sweep, then replaced after
        // the prefetcher snapshot, must come back as the NEW version (the
        // stale in-flight delivery is dropped)
        let per = cp(0, 16, 0, 0).bytes();
        let (mut store, dir) = mk(per, "stale");
        for s in 0..6 {
            store.insert(cp(s, 16, 0, s as u64));
        }
        assert!(store.cold.contains(2));
        store.begin_reverse_sweep();
        // replace step 2 while its old record is in the prefetch stream
        let replacement = cp(2, 16, 0, 999);
        store.insert(replacement.clone());
        for s in (0..6).rev() {
            let got = store.take(s).expect("present");
            if s == 2 {
                assert_eq!(got.u, replacement.u, "stale prefetch payload must not win");
            }
        }
        store.finish();
        assert!(store.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fleet_of_stores_shares_one_arbiter_pool() {
        use crate::exec::arbiter::BudgetArbiter;
        let per = cp(0, 64, 2, 0).bytes();
        let arb = BudgetArbiter::new(4 * per);
        let dir = tmp_dir("fleet");
        let mk_leased = |tag: usize| {
            let mut cfg = TieredConfig::new(4 * per, dir.join(format!("s{tag}")));
            cfg.arbiter = Some(arb.clone());
            TieredStore::create(cfg).unwrap()
        };
        let mut a = mk_leased(0);
        let mut b = mk_leased(1);
        let originals: Vec<StepCheckpoint> =
            (0..8).map(|s| cp(s, 64, 2, s as u64)).collect();
        for c in &originals {
            a.insert(c.clone());
            b.insert(c.clone());
        }
        // combined demand is 16 records against a 4-record pool: the fleet
        // degrades by spilling, and the concurrent hot footprint never
        // exceeds the pool
        assert!(a.stats().spills > 0 && b.stats().spills > 0);
        let st = arb.stats();
        assert!(st.peak_leased <= 4 * per, "{st:?}");
        assert!(st.lease_waits > 0, "an over-subscribed fleet must contend: {st:?}");
        assert_eq!(st.over_grant_bytes, 0, "floors fit the pool here: {st:?}");

        a.begin_reverse_sweep();
        b.begin_reverse_sweep();
        for c in originals.iter().rev() {
            assert_eq!(a.take(c.step).expect("in a").u, c.u, "step {} a", c.step);
            assert_eq!(b.take(c.step).expect("in b").u, c.u, "step {} b", c.step);
        }
        a.finish();
        b.finish();
        assert!(a.is_empty() && b.is_empty());
        assert_eq!(arb.stats().leased, 0, "all bytes returned: {:?}", arb.stats());
        assert!(arb.stats().peak_leased <= 4 * per);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mandatory_floor_keeps_one_record_and_counts_overdraw() {
        use crate::exec::arbiter::BudgetArbiter;
        let per = cp(0, 32, 0, 0).bytes();
        // a pool smaller than a single record: the store must still keep
        // its working record resident (degrade, don't deadlock)
        let arb = BudgetArbiter::new(per / 2);
        let dir = tmp_dir("floor");
        let mut cfg = TieredConfig::new(per / 2, &dir);
        cfg.arbiter = Some(arb.clone());
        let mut store = TieredStore::create(cfg).unwrap();
        for s in 0..4 {
            store.insert(cp(s, 32, 0, s as u64));
        }
        assert_eq!(store.hot.len(), 1, "everything but the working record spills");
        let st = arb.stats();
        assert!(st.over_grant_bytes >= per - per / 2, "overdraw counted: {st:?}");
        store.begin_reverse_sweep();
        for s in (0..4).rev() {
            assert!(store.take(s).is_some(), "step {s}");
        }
        store.finish();
        assert_eq!(arb.stats().leased, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn peak_accounts_hot_plus_prefetched() {
        let per = cp(0, 64, 0, 0).bytes();
        let (mut store, dir) = mk(3 * per, "peak");
        for s in 0..9 {
            store.insert(cp(s, 64, 0, s as u64));
        }
        let peak_fwd = store.peak_hot_bytes();
        assert!(peak_fwd <= 3 * per + per, "eviction keeps peak near budget");
        store.begin_reverse_sweep();
        for s in (0..9).rev() {
            store.take(s);
        }
        store.finish();
        assert!(store.peak_hot_bytes() >= peak_fwd);
        assert_eq!(store.hot_bytes(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
