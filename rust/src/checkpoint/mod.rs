//! Checkpointing for the discrete adjoint: byte-accounted storage,
//! policies (All / SolutionOnly / Binomial / Tiered), the Prop-2 closed
//! form, a DP-optimal binomial scheduler for multistage schemes, and the
//! tiered (RAM-budget + disk-spill + reverse-prefetch) storage backend.

pub mod binomial;
pub mod policy;
pub mod store;
pub mod tiered;

pub use binomial::{optimal_extra_steps, prop2_extra_steps, BinomialPlanner};
pub use policy::CheckpointPolicy;
pub use store::{CheckpointStore, StepCheckpoint};
pub use tiered::{CheckpointBackend, MemoryBudget, TierStats, TieredConfig, TieredStore};
