//! Checkpointing for the discrete adjoint: byte-accounted storage,
//! policies (All / SolutionOnly / Binomial), the Prop-2 closed form, and a
//! DP-optimal binomial scheduler for multistage schemes.

pub mod binomial;
pub mod policy;
pub mod store;

pub use binomial::{optimal_extra_steps, prop2_extra_steps, BinomialPlanner};
pub use policy::CheckpointPolicy;
pub use store::{CheckpointStore, StepCheckpoint};
