//! Checkpoint storage with exact byte accounting.
//!
//! A step checkpoint holds the solution `u_n` and optionally the stage
//! derivatives `k_i` of the step departing from `t_n` (the paper's
//! "solutions ... with the stage values"); size = `(N_s + 1) × state` f32s
//! when stages are kept, matching the Table-2 memory column.  Peak bytes
//! are tracked so benchmarks report *measured* checkpoint memory alongside
//! the analytic model.

use std::collections::BTreeMap;

/// One stored step.
#[derive(Clone, Debug)]
pub struct StepCheckpoint {
    pub step: usize,
    pub t: f64,
    pub h: f64,
    pub u: Vec<f32>,
    /// stage derivatives `k_i`, present under stage-storing policies
    pub ks: Option<Vec<Vec<f32>>>,
}

impl StepCheckpoint {
    pub fn bytes(&self) -> u64 {
        let mut elems = self.u.len();
        if let Some(ks) = &self.ks {
            elems += ks.iter().map(|k| k.len()).sum::<usize>();
        }
        (elems * std::mem::size_of::<f32>()) as u64 + 48 // struct overhead
    }
}

/// Step-indexed checkpoint store.
#[derive(Debug, Default)]
pub struct CheckpointStore {
    slots: BTreeMap<usize, StepCheckpoint>,
    bytes: u64,
    peak_bytes: u64,
}

impl CheckpointStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, cp: StepCheckpoint) {
        let step = cp.step;
        let add = cp.bytes();
        if let Some(old) = self.slots.insert(step, cp) {
            self.bytes -= old.bytes();
        }
        self.bytes += add;
        self.peak_bytes = self.peak_bytes.max(self.bytes);
    }

    pub fn remove(&mut self, step: usize) -> Option<StepCheckpoint> {
        let cp = self.slots.remove(&step)?;
        self.bytes -= cp.bytes();
        Some(cp)
    }

    pub fn get(&self, step: usize) -> Option<&StepCheckpoint> {
        self.slots.get(&step)
    }

    /// Latest checkpoint at or below `step`.
    pub fn nearest_at_or_below(&self, step: usize) -> Option<&StepCheckpoint> {
        self.slots.range(..=step).next_back().map(|(_, cp)| cp)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }

    /// Drop everything and reset accounting, peak included: a cleared
    /// store begins a fresh run (the adjoint driver clears at the top of
    /// every forward pass), so peaks report per-run, not lifetime, memory.
    pub fn clear(&mut self) {
        self.slots.clear();
        self.bytes = 0;
        self.peak_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cp(step: usize, n: usize, stages: usize) -> StepCheckpoint {
        StepCheckpoint {
            step,
            t: step as f64,
            h: 1.0,
            u: vec![0.0; n],
            ks: if stages > 0 { Some(vec![vec![0.0; n]; stages]) } else { None },
        }
    }

    #[test]
    fn byte_accounting_tracks_peak() {
        let mut s = CheckpointStore::new();
        s.insert(cp(0, 100, 4)); // (4+1)*100*4 + 48 = 2048
        assert_eq!(s.bytes(), 2048);
        s.insert(cp(1, 100, 0)); // 100*4+48 = 448
        assert_eq!(s.bytes(), 2048 + 448);
        assert_eq!(s.peak_bytes(), 2048 + 448);
        s.remove(0);
        assert_eq!(s.bytes(), 448);
        assert_eq!(s.peak_bytes(), 2048 + 448, "peak sticks");
    }

    #[test]
    fn reinsert_replaces_without_leak() {
        let mut s = CheckpointStore::new();
        s.insert(cp(3, 10, 0));
        let b1 = s.bytes();
        s.insert(cp(3, 10, 2));
        assert_eq!(s.len(), 1);
        assert!(s.bytes() > b1);
        s.remove(3);
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn peak_accounting_across_evict_restore_cycles() {
        // simulate the binomial executor's churn: evict (remove) and
        // restore (re-insert) the same steps repeatedly; `bytes` must
        // return to baseline every cycle and `peak` must only ratchet up.
        let mut s = CheckpointStore::new();
        for step in 0..4 {
            s.insert(cp(step, 50, 2)); // 3*50*4+48 = 648 each
        }
        let baseline = s.bytes();
        assert_eq!(baseline, 4 * 648);
        let mut peak = s.peak_bytes();
        for cycle in 0..5 {
            let evicted: Vec<StepCheckpoint> =
                (0..2).map(|step| s.remove(step).unwrap()).collect();
            assert_eq!(s.bytes(), baseline - 2 * 648, "cycle {cycle}");
            for cp in evicted {
                s.insert(cp);
            }
            assert_eq!(s.bytes(), baseline, "cycle {cycle}: restore is lossless");
            assert!(s.peak_bytes() >= peak, "peak never decreases");
            peak = s.peak_bytes();
        }
        // an extra transient resident raises the peak exactly once
        s.insert(cp(99, 50, 2));
        assert_eq!(s.peak_bytes(), baseline + 648);
        s.remove(99);
        assert_eq!(s.bytes(), baseline);
        assert_eq!(s.peak_bytes(), baseline + 648, "peak sticks after the transient");
    }

    #[test]
    fn clear_resets_bytes_and_peak_for_the_next_run() {
        let mut s = CheckpointStore::new();
        s.insert(cp(1, 10, 1));
        s.insert(cp(2, 10, 1));
        assert!(s.peak_bytes() > 0);
        s.clear();
        assert_eq!(s.bytes(), 0);
        assert_eq!(s.len(), 0);
        assert_eq!(s.peak_bytes(), 0, "peak is per-run, not lifetime");
        assert!(s.get(1).is_none());
        // reuse after clear keeps accounting exact
        s.insert(cp(3, 10, 0));
        assert_eq!(s.bytes(), 10 * 4 + 48);
        assert_eq!(s.peak_bytes(), 10 * 4 + 48);
    }

    #[test]
    fn nearest_lookup() {
        let mut s = CheckpointStore::new();
        for step in [0usize, 4, 9] {
            s.insert(cp(step, 2, 0));
        }
        assert_eq!(s.nearest_at_or_below(6).unwrap().step, 4);
        assert_eq!(s.nearest_at_or_below(4).unwrap().step, 4);
        assert_eq!(s.nearest_at_or_below(100).unwrap().step, 9);
        assert_eq!(s.nearest_at_or_below(3).unwrap().step, 0);
    }
}
