//! Checkpointing policies (the PNODE memory/compute trade-off knob).

/// How the forward pass checkpoints and what the backward pass recomputes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckpointPolicy {
    /// Store solution + stages at every step: zero recomputation, the
    /// paper's default "PNODE" configuration (worst-case memory).
    All,
    /// Store solutions only ("PNODE2"): N_t - 1 step recomputations,
    /// memory shrinks by the stage factor.
    SolutionOnly,
    /// Binomial (Revolve-style) with at most `n_checkpoints` slots:
    /// recomputation given by the optimal schedule / Prop. 2.
    Binomial { n_checkpoints: usize },
}

impl CheckpointPolicy {
    pub fn parse(s: &str) -> Option<CheckpointPolicy> {
        if let Some(rest) = s.strip_prefix("binomial:") {
            return rest.parse().ok().map(|n| CheckpointPolicy::Binomial { n_checkpoints: n });
        }
        match s {
            "all" => Some(CheckpointPolicy::All),
            "solution" | "solution_only" | "pnode2" => Some(CheckpointPolicy::SolutionOnly),
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            CheckpointPolicy::All => "all".into(),
            CheckpointPolicy::SolutionOnly => "solution_only".into(),
            CheckpointPolicy::Binomial { n_checkpoints } => format!("binomial:{n_checkpoints}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for p in [
            CheckpointPolicy::All,
            CheckpointPolicy::SolutionOnly,
            CheckpointPolicy::Binomial { n_checkpoints: 7 },
        ] {
            assert_eq!(CheckpointPolicy::parse(&p.name()), Some(p));
        }
        assert_eq!(CheckpointPolicy::parse("bogus"), None);
    }
}
