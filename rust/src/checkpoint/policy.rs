//! Checkpointing policies (the PNODE memory/compute trade-off knob).

use crate::checkpoint::tiered::MemoryBudget;

/// How the forward pass checkpoints and what the backward pass recomputes.
///
/// `All` / `SolutionOnly` / `Binomial` govern *placement* (which steps are
/// stored, with or without stages).  `Tiered` is orthogonal: it reuses one
/// of those placements (`inner`) but routes the stored checkpoints through
/// the budgeted RAM-tier/disk-spill backend instead of keeping everything
/// resident — so `Tiered{inner: Binomial{..}}` composes the Revolve
/// schedule with bounded host memory.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CheckpointPolicy {
    /// Store solution + stages at every step: zero recomputation, the
    /// paper's default "PNODE" configuration (worst-case memory).
    All,
    /// Store solutions only ("PNODE2"): N_t - 1 step recomputations,
    /// memory shrinks by the stage factor.
    SolutionOnly,
    /// Binomial (Revolve-style) with at most `n_checkpoints` slots:
    /// recomputation given by the optimal schedule / Prop. 2.
    Binomial { n_checkpoints: usize },
    /// Tiered storage: `inner` placement, hot-tier RAM capped at
    /// `budget_bytes`, overflow spilled to a file under `dir` (optionally
    /// f16-compressed), streamed back by a reverse-order prefetcher during
    /// the adjoint sweep.
    Tiered {
        budget_bytes: u64,
        /// spill directory (created on demand; the spill file is deleted
        /// when the run is dropped)
        dir: String,
        /// store cold payloads as f16 (2× smaller, lossy, error-accounted)
        compress_f16: bool,
        /// placement policy: `All`, `SolutionOnly`, or `Binomial`
        inner: Box<CheckpointPolicy>,
    },
    /// Resolve the cheapest concrete policy under a RAM budget at
    /// `Session`/registry build time, using the ledger-calibrated cost
    /// model (`crate::obs::calibrate`, DESIGN.md §13).  Engines never see
    /// this variant: the facade replaces it with the winning concrete
    /// policy before the engine is constructed, and records both the
    /// requested budget and the resolution in the run report.
    Auto { budget_bytes: u64 },
}

impl CheckpointPolicy {
    /// Parse a policy spec.  Grammar:
    ///
    /// ```text
    /// all | solution | solution_only | pnode2
    /// binomial:<n>                          n >= 1
    /// tiered:<budget>[+f16]:<dir>[:<inner>] budget e.g. 4096 / 64k / 8m / 1g
    /// auto:<budget>                         resolved by the cost model
    /// ```
    ///
    /// Degenerate specs (`binomial:0`, zero budgets, nested `tiered`,
    /// `auto` as a tiered inner) are rejected with a message naming the
    /// offending part rather than constructing a policy whose schedule
    /// can never run.
    pub fn parse(s: &str) -> Result<CheckpointPolicy, String> {
        if let Some(rest) = s.strip_prefix("binomial:") {
            let n: usize = rest
                .parse()
                .map_err(|_| format!("bad binomial checkpoint count {rest:?} in {s:?}"))?;
            let p = CheckpointPolicy::Binomial { n_checkpoints: n };
            p.validate().map_err(|e| format!("{s:?}: {e}"))?;
            return Ok(p);
        }
        if let Some(rest) = s.strip_prefix("tiered:") {
            let (budget_part, rest) = rest
                .split_once(':')
                .ok_or_else(|| format!("tiered policy {s:?} is missing the spill dir (want tiered:<budget>:<dir>[:<inner>])"))?;
            let (budget_spec, compress_f16) = match budget_part.strip_suffix("+f16") {
                Some(b) => (b, true),
                None => (budget_part, false),
            };
            let budget = MemoryBudget::parse(budget_spec).map_err(|e| format!("{s:?}: {e}"))?;
            if rest.contains(":tiered:") || rest.starts_with("tiered:") {
                return Err(format!("{s:?}: tiered policies cannot nest"));
            }
            // the inner policy is recognized from the END of the spec, so
            // the dir itself may contain ':' (Windows drives, URL-ish
            // paths) and name() round-trips for any dir
            let (dir, inner) = match split_inner_suffix(rest) {
                Some((dir, inner_spec)) => {
                    let inner = CheckpointPolicy::parse(inner_spec)
                        .map_err(|e| format!("{s:?}: bad inner policy: {e}"))?;
                    (dir, inner)
                }
                None => (rest, CheckpointPolicy::All),
            };
            let p = CheckpointPolicy::Tiered {
                budget_bytes: budget.bytes,
                dir: dir.to_string(),
                compress_f16,
                inner: Box::new(inner),
            };
            p.validate().map_err(|e| format!("{s:?}: {e}"))?;
            return Ok(p);
        }
        if let Some(rest) = s.strip_prefix("auto:") {
            let budget = MemoryBudget::parse(rest).map_err(|e| format!("{s:?}: {e}"))?;
            let p = CheckpointPolicy::Auto { budget_bytes: budget.bytes };
            p.validate().map_err(|e| format!("{s:?}: {e}"))?;
            return Ok(p);
        }
        match s {
            "all" => Ok(CheckpointPolicy::All),
            "solution" | "solution_only" | "pnode2" => Ok(CheckpointPolicy::SolutionOnly),
            _ => Err(format!(
                "unknown checkpoint policy {s:?} (want all | solution_only | binomial:<n> | \
                 tiered:<budget>:<dir>[:<inner>] | auto:<budget>)"
            )),
        }
    }

    /// Reject degenerate policies with a message naming the offending
    /// part.  The single source of truth for these rules: [`parse`]
    /// funnels through it (so string specs inherit them), and the typed
    /// facade path (`crate::api::MethodSpec::validate`) calls it for
    /// programmatic constructions the parser never sees.
    ///
    /// [`parse`]: CheckpointPolicy::parse
    pub fn validate(&self) -> Result<(), String> {
        match self {
            CheckpointPolicy::Binomial { n_checkpoints: 0 } => Err(
                "binomial:0 is degenerate: the Revolve schedule needs at least one \
                 checkpoint slot (use n >= 1, or `solution_only`)"
                    .into(),
            ),
            CheckpointPolicy::Tiered { budget_bytes, dir, inner, .. } => {
                if *budget_bytes == 0 {
                    return Err("tiered hot-tier budget must be nonzero".into());
                }
                if dir.is_empty() {
                    return Err("tiered spill dir must be nonempty".into());
                }
                if matches!(inner.as_ref(), CheckpointPolicy::Tiered { .. }) {
                    return Err("tiered policies cannot nest".into());
                }
                if matches!(inner.as_ref(), CheckpointPolicy::Auto { .. }) {
                    return Err(
                        "auto cannot be a tiered inner policy: the placement must be \
                         concrete (all | solution_only | binomial:<n>); put the budget \
                         on `auto:<budget>` at the top level instead"
                            .into(),
                    );
                }
                inner.validate()
            }
            CheckpointPolicy::Auto { budget_bytes: 0 } => Err(
                "auto:0 is degenerate: the auto policy needs a nonzero RAM budget to \
                 select a candidate under (e.g. auto:8m)"
                    .into(),
            ),
            _ => Ok(()),
        }
    }

    pub fn name(&self) -> String {
        match self {
            CheckpointPolicy::All => "all".into(),
            CheckpointPolicy::SolutionOnly => "solution_only".into(),
            CheckpointPolicy::Binomial { n_checkpoints } => format!("binomial:{n_checkpoints}"),
            CheckpointPolicy::Tiered { budget_bytes, dir, compress_f16, inner } => {
                format!(
                    "tiered:{}{}:{}:{}",
                    MemoryBudget::from_bytes(*budget_bytes).display(),
                    if *compress_f16 { "+f16" } else { "" },
                    dir,
                    inner.name()
                )
            }
            CheckpointPolicy::Auto { budget_bytes } => {
                format!("auto:{}", MemoryBudget::from_bytes(*budget_bytes).display())
            }
        }
    }

    /// The placement policy: which steps get stored, and whether stages
    /// ride along.  Identity for non-tiered policies; unwraps nested
    /// `Tiered` layers fully (the parser rejects nesting, but the variant
    /// is public, so be total rather than panic downstream).
    pub fn placement(&self) -> &CheckpointPolicy {
        let mut p = self;
        while let CheckpointPolicy::Tiered { inner, .. } = p {
            p = inner.as_ref();
        }
        p
    }

    /// Whether stored checkpoints carry stage derivatives.
    pub fn stores_stages(&self) -> bool {
        !matches!(self.placement(), CheckpointPolicy::SolutionOnly)
    }
}

/// Split `<dir>[:<inner-policy>]` by recognizing a valid inner-policy spec
/// at the *end* of the string (`:all`, `:solution_only`, `:solution`,
/// `:pnode2`, `:binomial:<digits>`, `:auto:<budget>`); everything before
/// it is the dir.  `auto` is recognized here only so that `validate` can
/// reject the nesting with a precise message instead of silently folding
/// the suffix into the dir.
fn split_inner_suffix(rest: &str) -> Option<(&str, &str)> {
    for suffix in [":all", ":solution_only", ":solution", ":pnode2"] {
        if let Some(dir) = rest.strip_suffix(suffix) {
            return Some((dir, &suffix[1..]));
        }
    }
    if let Some(pos) = rest.rfind(":binomial:") {
        let digits = &rest[pos + ":binomial:".len()..];
        if !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit()) {
            return Some((&rest[..pos], &rest[pos + 1..]));
        }
    }
    if let Some(pos) = rest.rfind(":auto:") {
        let budget = &rest[pos + ":auto:".len()..];
        if MemoryBudget::parse(budget).is_ok() {
            return Some((&rest[..pos], &rest[pos + 1..]));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for p in [
            CheckpointPolicy::All,
            CheckpointPolicy::SolutionOnly,
            CheckpointPolicy::Binomial { n_checkpoints: 7 },
            CheckpointPolicy::Tiered {
                budget_bytes: 8 << 20,
                dir: "/tmp/spill".into(),
                compress_f16: false,
                inner: Box::new(CheckpointPolicy::All),
            },
            CheckpointPolicy::Tiered {
                budget_bytes: 64 << 10,
                dir: "spill_dir".into(),
                compress_f16: true,
                inner: Box::new(CheckpointPolicy::Binomial { n_checkpoints: 5 }),
            },
        ] {
            assert_eq!(CheckpointPolicy::parse(&p.name()), Ok(p.clone()), "{}", p.name());
        }
        assert!(CheckpointPolicy::parse("bogus").is_err());
    }

    #[test]
    fn degenerate_specs_are_rejected_with_context() {
        let e = CheckpointPolicy::parse("binomial:0").unwrap_err();
        assert!(e.contains("binomial:0") && e.contains("at least one"), "{e}");
        assert!(CheckpointPolicy::parse("binomial:").is_err());
        assert!(CheckpointPolicy::parse("binomial:x").is_err());
        assert!(CheckpointPolicy::parse("binomial:-2").is_err());
        let e = CheckpointPolicy::parse("tiered:0:/tmp/x").unwrap_err();
        assert!(e.contains("zero"), "{e}");
        assert!(CheckpointPolicy::parse("tiered:8m").is_err(), "missing dir");
        assert!(CheckpointPolicy::parse("tiered:8m:").is_err(), "empty dir");
        let e = CheckpointPolicy::parse("tiered:8m:/tmp/x:binomial:0").unwrap_err();
        assert!(e.contains("inner"), "{e}");
        let e = CheckpointPolicy::parse("tiered:8m:/tmp/x:tiered:8m:/tmp/y").unwrap_err();
        assert!(e.contains("nest"), "{e}");
    }

    #[test]
    fn auto_parse_roundtrip_and_rejection() {
        let p = CheckpointPolicy::parse("auto:8m").unwrap();
        assert_eq!(p, CheckpointPolicy::Auto { budget_bytes: 8 << 20 });
        assert_eq!(p.name(), "auto:8m");
        assert_eq!(CheckpointPolicy::parse(&p.name()), Ok(p));
        assert_eq!(
            CheckpointPolicy::parse("auto:4096").unwrap(),
            CheckpointPolicy::Auto { budget_bytes: 4096 }
        );
        // zero budget: rejected both through parse and through validate
        assert!(CheckpointPolicy::parse("auto:0").is_err());
        let e = CheckpointPolicy::Auto { budget_bytes: 0 }.validate().unwrap_err();
        assert!(e.contains("auto:0") && e.contains("nonzero"), "{e}");
        assert!(CheckpointPolicy::parse("auto:").is_err());
        assert!(CheckpointPolicy::parse("auto:x").is_err());
        // auto cannot nest inside tiered — precise message, not a silent
        // fold of ":auto:..." into the spill dir
        let e = CheckpointPolicy::parse("tiered:8m:/tmp/x:auto:4k").unwrap_err();
        assert!(e.contains("auto") && e.contains("concrete"), "{e}");
        let e = CheckpointPolicy::Tiered {
            budget_bytes: 4096,
            dir: "/tmp/x".into(),
            compress_f16: false,
            inner: Box::new(CheckpointPolicy::Auto { budget_bytes: 4096 }),
        }
        .validate()
        .unwrap_err();
        assert!(e.contains("concrete"), "{e}");
    }

    #[test]
    fn tiered_parse_shapes() {
        match CheckpointPolicy::parse("tiered:64k:/tmp/spill").unwrap() {
            CheckpointPolicy::Tiered { budget_bytes, dir, compress_f16, inner } => {
                assert_eq!(budget_bytes, 64 << 10);
                assert_eq!(dir, "/tmp/spill");
                assert!(!compress_f16);
                assert_eq!(*inner, CheckpointPolicy::All);
            }
            p => panic!("wrong variant {p:?}"),
        }
        match CheckpointPolicy::parse("tiered:1m+f16:sd:solution_only").unwrap() {
            CheckpointPolicy::Tiered { compress_f16, inner, .. } => {
                assert!(compress_f16);
                assert_eq!(*inner, CheckpointPolicy::SolutionOnly);
            }
            p => panic!("wrong variant {p:?}"),
        }
    }

    #[test]
    fn dirs_containing_colons_round_trip() {
        // the inner policy is recognized from the end, so Windows-style
        // and otherwise colon-bearing dirs survive name() -> parse()
        for dir in ["C:\\spill", "data:all:x", "/tmp/all", "/tmp/binomial:7-ish"] {
            for inner in [
                CheckpointPolicy::All,
                CheckpointPolicy::SolutionOnly,
                CheckpointPolicy::Binomial { n_checkpoints: 7 },
            ] {
                let p = CheckpointPolicy::Tiered {
                    budget_bytes: 4096,
                    dir: dir.into(),
                    compress_f16: false,
                    inner: Box::new(inner),
                };
                assert_eq!(CheckpointPolicy::parse(&p.name()), Ok(p.clone()), "{}", p.name());
            }
        }
        // bare colon-dir without an inner suffix parses as dir + default
        match CheckpointPolicy::parse("tiered:8m:C:\\spill").unwrap() {
            CheckpointPolicy::Tiered { dir, inner, .. } => {
                assert_eq!(dir, "C:\\spill");
                assert_eq!(*inner, CheckpointPolicy::All);
            }
            p => panic!("wrong variant {p:?}"),
        }
    }

    #[test]
    fn placement_and_stage_semantics() {
        let tiered = CheckpointPolicy::parse("tiered:8m:/tmp/x:binomial:4").unwrap();
        assert_eq!(
            tiered.placement(),
            &CheckpointPolicy::Binomial { n_checkpoints: 4 }
        );
        assert!(tiered.stores_stages());
        // programmatically nested (parser rejects it): placement unwraps fully
        let nested = CheckpointPolicy::Tiered {
            budget_bytes: 1024,
            dir: "/tmp/a".into(),
            compress_f16: false,
            inner: Box::new(tiered.clone()),
        };
        assert_eq!(
            nested.placement(),
            &CheckpointPolicy::Binomial { n_checkpoints: 4 }
        );
        let t2 = CheckpointPolicy::parse("tiered:8m:/tmp/x:pnode2").unwrap();
        assert!(!t2.stores_stages());
        assert!(CheckpointPolicy::All.stores_stages());
        assert!(!CheckpointPolicy::SolutionOnly.stores_stages());
    }
}
