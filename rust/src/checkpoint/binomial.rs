//! Binomial (Revolve-style) checkpointing for multistage time integrators.
//!
//! Two pieces:
//!
//! 1. [`prop2_extra_steps`] — the paper's Proposition-2 closed form for the
//!    minimal number of recomputed forward steps,
//!        p̃(N_t, N_c) = (t-1) N_t − C(N_c+t, t−1) + 1,
//!    with t the unique integer s.t. C(N_c+t−1, t−1) < N_t ≤ C(N_c+t, t).
//!
//! 2. [`BinomialPlanner`] — a dynamic-programming scheduler that is optimal
//!    under the machine model below and is what the adjoint driver executes.
//!
//! Machine model (documented in DESIGN.md §5): a checkpoint stores the
//! solution u_m *and* the stage values of the step departing t_m; storing
//! during the original forward pass is free; storing during a recomputation
//! walk costs one extra step execution (to produce the stages); the stages
//! of the global last step are retained transiently from the forward pass;
//! adjoining a step whose checkpoint holds stages is free, otherwise the
//! step is re-executed once.  Under this model our DP can *match or beat*
//! the Prop-2 count (tests assert `optimal ≤ prop2` on a grid and equality
//! in the regimes the paper's tables exercise: N_c ≥ N_t−1 → 0 and
//! solution-only → N_t−1); the variance for small N_c comes from machine-
//! model details of [26] not recoverable from the paper text.

use std::collections::HashMap;

/// C(n, k) saturating at u64::MAX (avoids overflow in the t search).
fn binom(n: u64, k: u64) -> u64 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut acc: u64 = 1;
    for i in 0..k {
        acc = match acc.checked_mul(n - i) {
            Some(v) => v / (i + 1),
            None => return u64::MAX,
        };
    }
    acc
}

/// Proposition 2 (Zhang & Constantinescu): minimal extra forward steps to
/// adjoint `nt` steps with `nc` checkpoints.  Returns `None` if `nc == 0`.
pub fn prop2_extra_steps(nt: usize, nc: usize) -> Option<u64> {
    if nc == 0 || nt == 0 {
        return None;
    }
    let (nt64, nc64) = (nt as u64, nc as u64);
    if nt64 <= nc64 + 1 {
        return Some(0);
    }
    let mut t: u64 = 1;
    loop {
        let lo = binom(nc64 + t - 1, t - 1);
        let hi = binom(nc64 + t, t);
        if lo < nt64 && nt64 <= hi {
            break;
        }
        t += 1;
        if t > 128 {
            return None; // nt astronomically large
        }
    }
    Some((t - 1) * nt64 - binom(nc64 + t, t - 1) + 1)
}

/// What the backward executor should do for a block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockDecision {
    /// Adjoint the last step of the block directly (walk from the anchor,
    /// recompute its stages), then recurse on the rest.
    DirectLast,
    /// During the pass that crosses this block, store a checkpoint at
    /// `anchor + offset`, splitting the block.
    Split { offset: usize },
}

/// Anchor flavour of a block's left end.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Anchor {
    /// bare solution (e.g. u_0, or a walk-stored checkpoint without stages)
    Bare,
    /// full checkpoint: solution + stages of the departing step
    Full,
}

/// DP planner.  Costs are counted in *step executions* (one execution =
/// N_s stage evaluations).
pub struct BinomialPlanner {
    /// (n, c, anchor, fwd_active) -> cost
    memo: HashMap<(usize, usize, Anchor, bool), u64>,
}

impl Default for BinomialPlanner {
    fn default() -> Self {
        Self::new()
    }
}

impl BinomialPlanner {
    pub fn new() -> Self {
        BinomialPlanner { memo: HashMap::new() }
    }

    /// Minimal extra steps under the documented machine model.
    pub fn optimal_cost(&mut self, nt: usize, nc: usize) -> u64 {
        self.cost(nt, nc, Anchor::Bare, true)
    }

    fn cost(&mut self, n: usize, c: usize, anchor: Anchor, fwd: bool) -> u64 {
        if n == 0 {
            return 0;
        }
        if n == 1 {
            return match (anchor, fwd) {
                (_, true) => 0,          // last-step stages retained from the pass
                (Anchor::Full, false) => 0, // stages in the checkpoint
                (Anchor::Bare, false) => 1, // re-execute the step
            };
        }
        if let Some(&v) = self.memo.get(&(n, c, anchor, fwd)) {
            return v;
        }
        // Option 1: adjoint the last step directly.
        let mut best = if fwd {
            // stages of the final step retained from the active pass
            self.cost(n - 1, c, anchor, false)
        } else {
            // walk n-1 steps from the anchor + 1 stage execution
            n as u64 + self.cost(n - 1, c, anchor, false)
        };
        // Option 2: split at m with a full checkpoint.
        if c >= 1 {
            for m in 1..n {
                // cost of creating the checkpoint at anchor+m:
                //   fwd active: free (the pass executes everything anyway)
                //   else: walk m steps + 1 extra execution for the stages
                let create = if fwd { 0 } else { m as u64 + 1 };
                let right = self.cost(n - m, c - 1, Anchor::Full, fwd);
                let left = self.cost(m, c, anchor, false);
                best = best.min(create + right + left);
            }
            // Option 3 (bare anchor only): upgrade the anchor itself.
            if anchor == Anchor::Bare {
                let create = if fwd { 0 } else { 1 };
                best = best.min(create + self.cost(n, c - 1, Anchor::Full, fwd));
            }
        }
        self.memo.insert((n, c, anchor, fwd), best);
        best
    }

    /// Decision for a block (what the executor consults).
    pub fn decide(&mut self, n: usize, c: usize, anchor: Anchor, fwd: bool) -> BlockDecision {
        if n <= 1 || c == 0 {
            return BlockDecision::DirectLast;
        }
        let best = self.cost(n, c, anchor, fwd);
        let direct = if fwd {
            self.cost(n - 1, c, anchor, false)
        } else {
            n as u64 + self.cost(n - 1, c, anchor, false)
        };
        if best == direct {
            return BlockDecision::DirectLast;
        }
        if anchor == Anchor::Bare {
            let create = if fwd { 0 } else { 1 };
            if best == create + self.cost(n, c - 1, Anchor::Full, fwd) {
                return BlockDecision::Split { offset: 0 };
            }
        }
        for m in 1..n {
            let create = if fwd { 0u64 } else { m as u64 + 1 };
            let total = create
                + self.cost(n - m, c - 1, Anchor::Full, fwd)
                + self.cost(m, c, anchor, false);
            if total == best {
                return BlockDecision::Split { offset: m };
            }
        }
        BlockDecision::DirectLast // unreachable in practice
    }

    /// Positions (relative to 0) where the original forward pass should
    /// store full checkpoints, given `nt` steps and `nc` slots.
    pub fn forward_store_positions(&mut self, nt: usize, nc: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut lo = 0usize;
        let mut n = nt;
        let mut c = nc;
        let mut anchor = Anchor::Bare;
        while n > 1 && c > 0 {
            match self.decide(n, c, anchor, true) {
                BlockDecision::Split { offset } => {
                    out.push(lo + offset);
                    if offset == 0 {
                        anchor = Anchor::Full;
                        c -= 1;
                    } else {
                        // right block becomes the next "active" block; the
                        // left block is handled later in the backward pass
                        lo += offset;
                        n -= offset;
                        c -= 1;
                        anchor = Anchor::Full;
                    }
                }
                BlockDecision::DirectLast => break,
            }
        }
        out
    }
}

/// Convenience wrapper: optimal extra steps under our machine model.
pub fn optimal_extra_steps(nt: usize, nc: usize) -> u64 {
    BinomialPlanner::new().optimal_cost(nt, nc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binom_basics() {
        assert_eq!(binom(5, 2), 10);
        assert_eq!(binom(10, 0), 1);
        assert_eq!(binom(3, 5), 0);
        assert_eq!(binom(60, 30) > 1_000_000_000, true);
    }

    #[test]
    fn prop2_known_values() {
        // sufficient memory: zero recomputation
        for nt in 1..=20 {
            assert_eq!(prop2_extra_steps(nt, nt.max(2) - 1), Some(0), "nt={nt}");
            assert_eq!(prop2_extra_steps(nt, 64), Some(0));
        }
        // hand-checked small cases ((t-1)Nt - C(Nc+t, t-1) + 1)
        assert_eq!(prop2_extra_steps(3, 1), Some(1));
        assert_eq!(prop2_extra_steps(4, 1), Some(3));
        assert_eq!(prop2_extra_steps(5, 1), Some(6));
        assert_eq!(prop2_extra_steps(10, 2), Some(11));
        assert_eq!(prop2_extra_steps(30, 3), Some(56));
        assert_eq!(prop2_extra_steps(0, 3), None);
        assert_eq!(prop2_extra_steps(5, 0), None);
    }

    #[test]
    fn dp_tracks_prop2_closely() {
        // The DP machine model and the paper's ([26]) differ in fine rules
        // (DESIGN.md §5); costs stay within a tight band of each other and
        // the DP's executed schedules are optimal under *our* model.
        let mut planner = BinomialPlanner::new();
        for nc in 1..=8usize {
            for nt in 2..=60usize {
                let dp = planner.cost(nt, nc, Anchor::Bare, true);
                let p2 = prop2_extra_steps(nt, nc).unwrap();
                assert!(
                    dp <= p2 + nt as u64,
                    "nt={nt} nc={nc}: dp {dp} way above prop2 {p2}"
                );
                // both models share the trivial lower bound
                if nt <= nc + 1 {
                    assert_eq!(dp, 0);
                    assert_eq!(p2, 0);
                }
            }
        }
    }

    #[test]
    fn dp_exact_in_table_regimes() {
        let mut planner = BinomialPlanner::new();
        // zero-recompute regime (PNODE default in all benchmark tables)
        for nt in 2..=40usize {
            assert_eq!(planner.cost(nt, nt - 1, Anchor::Bare, true), 0);
        }
        // matches prop2 exactly for the small-N_t band (nt <= nc + 2)
        for nc in 1..=6usize {
            for nt in 2..=(nc + 2) {
                let dp = planner.cost(nt, nc, Anchor::Bare, true);
                let p2 = prop2_extra_steps(nt, nc).unwrap();
                assert_eq!(dp, p2, "nt={nt} nc={nc}");
            }
        }
    }

    #[test]
    fn dp_monotone_in_checkpoints() {
        let mut planner = BinomialPlanner::new();
        for nt in [10usize, 25, 40] {
            let mut prev = u64::MAX;
            for nc in 1..=nt {
                let c = planner.cost(nt, nc, Anchor::Bare, true);
                assert!(c <= prev, "nt={nt}: cost increased at nc={nc}");
                prev = c;
            }
            assert_eq!(prev, 0);
        }
    }

    #[test]
    fn single_checkpoint_schedules_are_executable() {
        // n_checkpoints == 1 is the tightest legal budget: the DP must
        // still produce a finite schedule whose decisions terminate.
        let mut planner = BinomialPlanner::new();
        for nt in 2..=40usize {
            let cost = planner.cost(nt, 1, Anchor::Bare, true);
            assert!(cost < (nt * nt) as u64, "nt={nt}: cost {cost} blows up");
            let pos = planner.forward_store_positions(nt, 1);
            assert!(pos.len() <= 1, "nt={nt}: {pos:?}");
        }
        // cost is strictly increasing in nt once recomputation kicks in
        let c3 = planner.cost(3, 1, Anchor::Bare, true);
        let c10 = planner.cost(10, 1, Anchor::Bare, true);
        assert!(c10 > c3);
    }

    #[test]
    fn oversized_budgets_never_recompute() {
        // n_checkpoints >= n_steps (and the boundary nc = nt-1): every
        // step can stay resident, so the optimal schedule recomputes
        // nothing and the forward pass stores at most nt positions.
        let mut planner = BinomialPlanner::new();
        for nt in 1..=30usize {
            for nc in [nt.max(2) - 1, nt, nt + 1, 4 * nt] {
                let cost = planner.cost(nt, nc, Anchor::Bare, true);
                assert_eq!(cost, 0, "nt={nt} nc={nc}");
                let pos = planner.forward_store_positions(nt, nc);
                assert!(pos.len() <= nt, "nt={nt} nc={nc}: {pos:?}");
                for w in pos.windows(2) {
                    assert!(w[0] < w[1]);
                }
            }
        }
    }

    #[test]
    fn forward_positions_fit_slots_and_range() {
        let mut planner = BinomialPlanner::new();
        for (nt, nc) in [(10usize, 3usize), (25, 4), (40, 2), (7, 7)] {
            let pos = planner.forward_store_positions(nt, nc);
            assert!(pos.len() <= nc, "nt={nt} nc={nc}: {pos:?}");
            for &p in &pos {
                assert!(p < nt);
            }
            // strictly increasing
            for w in pos.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }
}
