//! The session pool and request batcher behind [`crate::serve`].
//!
//! Concurrency layout: one submission queue (mutex + condvar) feeds
//! `sessions` worker threads.  Each worker owns a warm
//! [`Session`], one RHS instance built at the coalescing width, and two
//! fixed `max_batch × dim` gather/scatter buffers — so after its first
//! sweep a worker's forward path allocates nothing but the per-request
//! result rows it hands back.  All timing runs on one monotonic
//! [`crate::obs::Stopwatch`] epoch (wall-clock types never appear here:
//! the module sits under the `determinism` lint like the rest of the
//! numeric core).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::api::{RunSpec, Session};
use crate::exec::{BudgetArbiter, ExecStats};
use crate::obs;
use crate::ode::rhs::OdeRhs;
use crate::serve::{quantile, ServeConfig, ServeReport};

/// Queue/stat locks that shrug off poisoning: every critical section is
/// a handful of counter updates and buffer moves that leave the state
/// consistent, and refusing to serve after one worker's panic would turn
/// a single bad request into a fleet outage.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// One queued request.
struct Request {
    u0: Vec<f32>,
    /// epoch stamp at submit (latency = scatter stamp − this)
    enq_secs: f64,
    slot: Arc<Slot>,
}

/// The response rendezvous a [`Ticket`] blocks on.
struct Slot {
    result: Mutex<Option<Vec<f32>>>,
    done: Condvar,
}

/// Handle returned by [`ServePool::submit`]; redeem with
/// [`Ticket::wait`].
pub struct Ticket {
    slot: Arc<Slot>,
}

impl Ticket {
    /// Block until the pool has served this request; returns the final
    /// state row.
    pub fn wait(self) -> Vec<f32> {
        let mut st = lock(&self.slot.result);
        loop {
            if let Some(out) = st.take() {
                return out;
            }
            st = match self.slot.done.wait(st) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }
}

struct Queue {
    pending: VecDeque<Request>,
    closed: bool,
}

#[derive(Clone, Copy, Default)]
struct WorkerStats {
    requests: u64,
    batches: u64,
    /// seconds spent inside sweeps (admission + forward + scatter)
    busy_secs: f64,
    /// the owning session's forward-workspace (re)allocation count
    forward_allocs: u64,
}

#[derive(Default)]
struct Stats {
    requests: u64,
    batches: u64,
    /// per-request latency samples, seconds
    latencies: Vec<f64>,
    /// epoch stamp of the first submit / the latest completion
    first_enq: Option<f64>,
    last_done: f64,
    workers: Vec<WorkerStats>,
}

struct Shared {
    cfg: ServeConfig,
    /// per-request state row length
    dim: usize,
    /// resolved per-sweep admission lease (see [`ServeConfig::session_bytes`])
    session_bytes: u64,
    queue: Mutex<Queue>,
    /// wakes workers on submit and on shutdown
    arrived: Condvar,
    stats: Mutex<Stats>,
    /// session-level admission (None = unlimited)
    arbiter: Option<Arc<BudgetArbiter>>,
    /// monotonic epoch for every latency stamp
    epoch: obs::Stopwatch,
}

/// A fixed fleet of warm sessions serving coalesced forward-only
/// requests.  See the [module docs](crate::serve) for the coalescing
/// rule, the bitwise scatter contract, and the admission protocol.
pub struct ServePool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServePool {
    /// Build a warm fleet from `spec`.  `dim` is the per-request state
    /// length; `rhs_factory(rows)` builds the dynamics over a `rows`-row
    /// batch (each worker calls it once, at the coalescing width
    /// `cfg.max_batch`, and reuses that instance — packed θ, scratch and
    /// all — for its whole lifetime).
    ///
    /// Serving requires a *static* grid and an explicit scheme: adaptive
    /// step control couples batch rows through the WRMS error norm (a
    /// request's bits would depend on its batch-mates), and implicit
    /// θ-schemes fall back to the allocating engine path.
    pub fn new<F>(
        spec: &RunSpec,
        dim: usize,
        cfg: ServeConfig,
        rhs_factory: F,
    ) -> Result<ServePool, String>
    where
        F: Fn(usize) -> Box<dyn OdeRhs + Send>,
    {
        cfg.validate()?;
        let block = spec.block_spec();
        if !block.grid.is_static() {
            return Err(format!(
                "serve pool needs a static grid (uniform/explicit), got {}: adaptive step \
                 control couples batch rows through the error norm, which would break the \
                 bitwise per-request scatter contract",
                block.grid.name()
            ));
        }
        if block.scheme.is_implicit() {
            return Err(format!(
                "serve pool needs an explicit scheme, got {}: the implicit forward falls \
                 back to the allocating engine path",
                block.scheme.name()
            ));
        }
        if dim == 0 {
            return Err("serve pool needs dim >= 1".into());
        }
        // one sweep's resident footprint: state ping-pong + stage
        // derivatives + FSAL/error scratch, all at the coalescing width
        let session_bytes = if cfg.session_bytes > 0 {
            cfg.session_bytes
        } else {
            let stages = block.scheme.tableau().s as u64;
            (stages + 5) * (cfg.max_batch * dim * std::mem::size_of::<f32>()) as u64
        };
        let arbiter = if cfg.pool_bytes > 0 {
            let arb = BudgetArbiter::new(cfg.pool_bytes);
            arb.set_parties(cfg.sessions);
            Some(arb)
        } else {
            None
        };
        let shared = Arc::new(Shared {
            dim,
            session_bytes,
            queue: Mutex::new(Queue { pending: VecDeque::new(), closed: false }),
            arrived: Condvar::new(),
            stats: Mutex::new(Stats {
                workers: vec![WorkerStats::default(); cfg.sessions],
                ..Stats::default()
            }),
            arbiter,
            epoch: obs::stopwatch(),
            cfg,
        });
        let mut workers = Vec::with_capacity(shared.cfg.sessions);
        for wid in 0..shared.cfg.sessions {
            let session = Session::new(spec.clone())?;
            let rhs = rhs_factory(shared.cfg.max_batch);
            let sh = shared.clone();
            workers.push(std::thread::spawn(move || worker_loop(wid, &sh, session, rhs)));
        }
        Ok(ServePool { shared, workers })
    }

    /// The per-request state length every submission must match.
    pub fn dim(&self) -> usize {
        self.shared.dim
    }

    /// Enqueue one request (`u0.len()` must equal [`ServePool::dim`]).
    /// Returns a [`Ticket`] to block on.  Dispatch follows the
    /// coalescing rule: `max_batch` pending requests, or
    /// `max_delay_secs` after the oldest arrived — whichever first.
    pub fn submit(&self, u0: Vec<f32>) -> Result<Ticket, String> {
        if u0.len() != self.shared.dim {
            return Err(format!(
                "request state length {} does not match the pool dim {}",
                u0.len(),
                self.shared.dim
            ));
        }
        let now = self.shared.epoch.elapsed_secs();
        let slot = Arc::new(Slot { result: Mutex::new(None), done: Condvar::new() });
        {
            let mut q = lock(&self.shared.queue);
            if q.closed {
                return Err("serve pool is shut down".into());
            }
            q.pending.push_back(Request { u0, enq_secs: now, slot: slot.clone() });
        }
        {
            let mut st = lock(&self.shared.stats);
            if st.first_enq.is_none() {
                st.first_enq = Some(now);
            }
        }
        self.shared.arrived.notify_one();
        Ok(Ticket { slot })
    }

    /// Snapshot the serving statistics so far (running pools included).
    pub fn stats(&self) -> ServeReport {
        let st = lock(&self.shared.stats);
        build_report(&self.shared, &st)
    }

    /// Close the queue, serve every pending request, join the fleet, and
    /// return the final statistics.
    pub fn shutdown(mut self) -> ServeReport {
        self.close_and_join();
        let st = lock(&self.shared.stats);
        build_report(&self.shared, &st)
    }

    fn close_and_join(&mut self) {
        {
            let mut q = lock(&self.shared.queue);
            q.closed = true;
        }
        self.shared.arrived.notify_all();
        for h in self.workers.drain(..) {
            // a panicked worker poisoned nothing we rely on (locks
            // recover); just reap the handle
            let _ = h.join();
        }
    }
}

impl Drop for ServePool {
    fn drop(&mut self) {
        // a dropped pool must not leave detached workers parked on the
        // queue condvar forever (idempotent after shutdown())
        self.close_and_join();
    }
}

fn worker_loop(wid: usize, sh: &Shared, mut session: Session, rhs: Box<dyn OdeRhs + Send>) {
    let d = sh.dim;
    let mb = sh.cfg.max_batch;
    let mut batch_u0 = vec![0.0f32; mb * d];
    let mut batch_uf = vec![0.0f32; mb * d];
    let mut taken: Vec<Request> = Vec::with_capacity(mb);
    let mut lat_scratch: Vec<f64> = Vec::with_capacity(mb);
    loop {
        // ---- coalesce: max_batch pending, or max_delay past the oldest
        {
            let mut q = lock(&sh.queue);
            loop {
                let now = sh.epoch.elapsed_secs();
                let age = q.pending.front().map(|r| now - r.enq_secs);
                let full = q.pending.len() >= mb;
                let expired = age.map(|a| a >= sh.cfg.max_delay_secs).unwrap_or(false);
                if full || expired || (q.closed && !q.pending.is_empty()) {
                    let k = q.pending.len().min(mb);
                    taken.extend(q.pending.drain(..k));
                    break;
                }
                if q.closed {
                    return; // drained and closed: fleet exit
                }
                let wait = match age {
                    // a batch is open: sleep only to its deadline
                    Some(a) => (sh.cfg.max_delay_secs - a).clamp(1e-4, 3600.0),
                    // queue empty: sleep until a submit (or shutdown) wakes us
                    None => 3600.0,
                };
                let (g, _timed_out) =
                    match sh.arrived.wait_timeout(q, Duration::from_secs_f64(wait)) {
                        Ok(v) => v,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                q = g;
            }
            if !q.pending.is_empty() {
                // leftovers: another worker can open its own batch now
                sh.arrived.notify_one();
            }
        }

        // ---- admission: the sweep's bytes in full, or queue (never OOM)
        let sweep_start = sh.epoch.elapsed_secs();
        let lease = sh.arbiter.as_ref().map(|a| a.acquire(sh.session_bytes));

        // ---- gather into the fixed max_batch × dim state; pad the tail
        // with copies of the last real row (row independence keeps real
        // rows' bits unchanged; the fixed shape keeps the workspace warm)
        let k = taken.len();
        for (i, r) in taken.iter().enumerate() {
            batch_u0[i * d..(i + 1) * d].copy_from_slice(&r.u0);
        }
        for i in k..mb {
            batch_u0.copy_within((k - 1) * d..k * d, i * d);
        }

        {
            let _sp = obs::span("serve.sweep");
            session.forward_into(rhs.as_ref(), &batch_u0, &mut batch_uf);
        }
        drop(lease);

        // ---- scatter: post each real row and wake its ticket
        let done = sh.epoch.elapsed_secs();
        for (i, r) in taken.drain(..).enumerate() {
            let row = batch_uf[i * d..(i + 1) * d].to_vec();
            {
                let mut out = lock(&r.slot.result);
                *out = Some(row);
            }
            r.slot.done.notify_all();
            lat_scratch.push(done - r.enq_secs);
        }

        {
            let mut st = lock(&sh.stats);
            st.requests += k as u64;
            st.batches += 1;
            st.latencies.extend_from_slice(&lat_scratch);
            st.last_done = st.last_done.max(done);
            let w = &mut st.workers[wid];
            w.requests += k as u64;
            w.batches += 1;
            w.busy_secs += done - sweep_start;
            w.forward_allocs = session.forward_allocs();
        }
        lat_scratch.clear();
    }
}

fn build_report(sh: &Shared, st: &Stats) -> ServeReport {
    let mut sorted = st.latencies.clone();
    sorted.sort_by(f64::total_cmp);
    let wall = st.first_enq.map(|t0| (st.last_done - t0).max(0.0)).unwrap_or(0.0);
    let mut exec = ExecStats::default();
    let mut forward_allocs = 0u64;
    let mut seeded = false;
    for w in &st.workers {
        forward_allocs += w.forward_allocs;
        let per = ExecStats {
            workers: 1,
            samples_per_sec: if w.busy_secs > 0.0 { w.requests as f64 / w.busy_secs } else { 0.0 },
            ..ExecStats::default()
        };
        if seeded {
            // concurrent sessions: fleet throughput is the sum
            exec.merge_sum(&per);
        } else {
            exec = per;
            seeded = true;
        }
    }
    exec.workers = sh.cfg.sessions as u64;
    if let Some(arb) = &sh.arbiter {
        let a = arb.stats();
        exec.lease_pool_bytes = a.total;
        exec.peak_leased_bytes = a.peak_leased;
        exec.lease_waits = a.lease_waits;
        exec.lease_denied_bytes = a.denied_bytes;
        exec.over_grant_bytes = a.over_grant_bytes;
    }
    ServeReport {
        requests: st.requests,
        batches: st.batches,
        sessions: sh.cfg.sessions,
        max_batch: sh.cfg.max_batch,
        requests_per_sec: if wall > 0.0 { st.requests as f64 / wall } else { 0.0 },
        p50_secs: quantile(&sorted, 0.50),
        p99_secs: quantile(&sorted, 0.99),
        mean_batch_rows: if st.batches > 0 {
            st.requests as f64 / st.batches as f64
        } else {
            0.0
        },
        forward_allocs,
        exec,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SolverBuilder;
    use crate::nn::Act;
    use crate::ode::{ModuleRhs, Scheme, TimeGrid};
    use crate::util::rng::Rng;

    fn theta(seed: u64) -> Vec<f32> {
        // concat-time MLP over 4 state channels: input is [u, t]
        let dims = vec![5, 8, 4];
        let mut rng = Rng::new(seed);
        crate::nn::init::kaiming_uniform(&mut rng, &dims, 1.0)
    }

    fn factory(seed: u64) -> impl Fn(usize) -> Box<dyn OdeRhs + Send> {
        move |rows| {
            Box::new(ModuleRhs::mlp(vec![5, 8, 4], Act::Tanh, true, rows, theta(seed)))
                as Box<dyn OdeRhs + Send>
        }
    }

    #[test]
    fn coalesced_results_match_isolated_sessions_bitwise() {
        let spec = SolverBuilder::new().uniform(5).build().unwrap();
        let cfg = ServeConfig { sessions: 2, max_batch: 4, ..Default::default() };
        let pool = ServePool::new(&spec, 4, cfg, factory(71)).unwrap();

        let mut rng = Rng::new(72);
        let mut requests = Vec::new();
        for _ in 0..10 {
            let mut u0 = vec![0.0f32; 4];
            rng.fill_normal(&mut u0);
            requests.push(u0);
        }
        let tickets: Vec<Ticket> =
            requests.iter().map(|u0| pool.submit(u0.clone()).unwrap()).collect();
        let served: Vec<Vec<f32>> = tickets.into_iter().map(Ticket::wait).collect();
        let report = pool.shutdown();

        let single = factory(71)(1);
        let mut isolated = Session::new(spec).unwrap();
        let mut out = vec![0.0f32; 4];
        for (u0, got) in requests.iter().zip(&served) {
            isolated.forward_into(single.as_ref(), u0, &mut out);
            assert_eq!(&out, got, "scatter must be bitwise = isolated run");
        }
        assert_eq!(report.requests, 10);
        assert!(report.batches >= 3, "10 requests / max_batch 4: {report:?}");
        assert!(report.p99_secs.is_finite() && report.p99_secs >= report.p50_secs);
    }

    #[test]
    fn steady_state_serving_never_reallocates_workspaces() {
        let spec = SolverBuilder::new().uniform(4).build().unwrap();
        let cfg = ServeConfig { sessions: 1, max_batch: 3, max_delay_secs: 1e-3, ..Default::default() };
        let pool = ServePool::new(&spec, 4, cfg, factory(81)).unwrap();
        let mut rng = Rng::new(82);
        for _wave in 0..4 {
            let tickets: Vec<Ticket> = (0..6)
                .map(|_| {
                    let mut u0 = vec![0.0f32; 4];
                    rng.fill_normal(&mut u0);
                    pool.submit(u0).unwrap()
                })
                .collect();
            for t in tickets {
                let _ = t.wait();
            }
        }
        let report = pool.shutdown();
        assert_eq!(report.requests, 24);
        assert_eq!(
            report.forward_allocs, 1,
            "one warm-up allocation for the whole fleet lifetime: {report:?}"
        );
    }

    #[test]
    fn admission_queues_oversubscribed_sweeps() {
        let spec = SolverBuilder::new().uniform(4).build().unwrap();
        // pool holds exactly one sweep's bytes: with 2 sessions, every
        // concurrent second sweep must queue on the arbiter
        let cfg = ServeConfig {
            sessions: 2,
            max_batch: 2,
            max_delay_secs: 1e-4,
            session_bytes: 1024,
            pool_bytes: 1024,
        };
        let pool = ServePool::new(&spec, 4, cfg, factory(91)).unwrap();
        let mut rng = Rng::new(92);
        let tickets: Vec<Ticket> = (0..40)
            .map(|_| {
                let mut u0 = vec![0.0f32; 4];
                rng.fill_normal(&mut u0);
                pool.submit(u0).unwrap()
            })
            .collect();
        for t in tickets {
            let _ = t.wait();
        }
        let report = pool.shutdown();
        assert_eq!(report.requests, 40);
        assert_eq!(report.exec.lease_pool_bytes, 1024);
        assert!(
            report.exec.peak_leased_bytes <= 1024,
            "admission must cap the concurrent footprint: {report:?}"
        );
    }

    #[test]
    fn pool_rejects_adaptive_grids_implicit_schemes_and_bad_requests() {
        let adaptive = SolverBuilder::new()
            .scheme(Scheme::Dopri5)
            .grid(TimeGrid::adaptive(1e-6))
            .build()
            .unwrap();
        let e = ServePool::new(&adaptive, 4, ServeConfig::default(), factory(1)).unwrap_err();
        assert!(e.contains("static grid"), "{e}");

        let implicit = SolverBuilder::new()
            .policy(crate::checkpoint::CheckpointPolicy::SolutionOnly)
            .scheme(Scheme::CrankNicolson)
            .uniform(4)
            .build()
            .unwrap();
        let e = ServePool::new(&implicit, 4, ServeConfig::default(), factory(1)).unwrap_err();
        assert!(e.contains("explicit scheme"), "{e}");

        let spec = SolverBuilder::new().uniform(4).build().unwrap();
        let pool = ServePool::new(&spec, 4, ServeConfig::default(), factory(1)).unwrap();
        let e = pool.submit(vec![0.0; 3]).unwrap_err();
        assert!(e.contains("does not match"), "{e}");
        let report = pool.shutdown();
        assert_eq!(report.requests, 0);
        assert_eq!(report.p99_secs, 0.0, "no requests, no latency");
    }
}
