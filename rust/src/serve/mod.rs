//! Forward-only inference serving (DESIGN.md §15): a warm session pool
//! with request coalescing and admission control, layered on the facade.
//!
//! The paper's accounting is about the *gradient* path; at inference
//! time a neural ODE needs no checkpoints and no adjoint sweep.  This
//! module turns [`crate::api::Session::forward_into`] — the
//! allocation-free forward path — into a serving engine:
//!
//! * **Session pool** ([`ServePool`]) — a fixed fleet of warm
//!   [`crate::api::Session`]s, each owning its grid plan, forward
//!   workspace, and one packed-θ RHS instance at the coalescing width,
//!   reused across every request it ever serves.
//! * **Request batcher** — a submission queue that coalesces compatible
//!   single-sample requests into shared minibatch sweeps.  The
//!   coalescing rule: a worker dispatches as soon as `max_batch`
//!   requests are pending, **or** `max_delay_secs` after the oldest
//!   pending request arrived — whichever comes first.  Partial batches
//!   are padded to `max_batch` rows (copies of the last real row) so
//!   the state shape — and with it the session workspace — never
//!   changes; padded rows are never scattered back.
//! * **Bitwise scatter contract** — batch rows are independent under a
//!   static grid (the [`crate::ode::rhs::OdeRhs::make_shard`] row-shard
//!   contract), so each scattered result is bitwise identical to
//!   running that request alone.  Adaptive grids are rejected at pool
//!   construction: the WRMS error norm couples rows, so a request's
//!   bits would depend on its batch-mates.  `tests/serve_determinism.rs`
//!   pins the contract across kernels and pool sizes.
//! * **Admission control** — with a nonzero [`ServeConfig::pool_bytes`],
//!   each sweep leases [`ServeConfig::session_bytes`] from a
//!   [`crate::exec::BudgetArbiter`] via the blocking
//!   [`crate::exec::BudgetArbiter::acquire`]: an over-subscribed fleet
//!   queues instead of OOM-ing, with `lease.wait` / denial counters
//!   flowing through the obs sink and into [`ServeReport::exec`].
//!
//! Throughput aggregates across the fleet with
//! [`crate::exec::ExecStats::merge_sum`] (concurrent sessions add,
//! unlike sequential blocks which `min`).

pub mod pool;

pub use pool::{ServePool, Ticket};

use crate::exec::ExecStats;
use crate::util::json::Json;

/// Serving knobs.  `Default` is a small two-session fleet with a 16-row
/// coalescing window and a 2 ms batching deadline.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// warm sessions in the fleet (dispatch concurrency)
    pub sessions: usize,
    /// coalescing cap: requests per minibatch sweep (and the fixed row
    /// count every sweep is padded to)
    pub max_batch: usize,
    /// coalescing deadline: seconds the oldest pending request may wait
    /// for the batch to fill before a partial sweep dispatches
    pub max_delay_secs: f64,
    /// admission: bytes one sweep leases while it runs (0 = derive a
    /// default from the state/workspace footprint)
    pub session_bytes: u64,
    /// admission pool in bytes (0 = no admission control)
    pub pool_bytes: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            sessions: 2,
            max_batch: 16,
            max_delay_secs: 2e-3,
            session_bytes: 0,
            pool_bytes: 0,
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.sessions == 0 {
            return Err("serve config needs sessions >= 1".into());
        }
        if self.max_batch == 0 {
            return Err("serve config needs max_batch >= 1".into());
        }
        if !(self.max_delay_secs.is_finite() && self.max_delay_secs >= 0.0) {
            return Err(format!(
                "serve config needs a finite nonnegative max_delay_secs, got {}",
                self.max_delay_secs
            ));
        }
        Ok(())
    }
}

/// Aggregate serving statistics (snapshot or final; see
/// [`ServePool::stats`] / [`ServePool::shutdown`]).
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// completed requests
    pub requests: u64,
    /// dispatched minibatch sweeps
    pub batches: u64,
    /// fleet size
    pub sessions: usize,
    /// coalescing cap the pool ran with
    pub max_batch: usize,
    /// completed requests per second of wall time (first submit to last
    /// completion)
    pub requests_per_sec: f64,
    /// median request latency (submit → result posted), seconds
    pub p50_secs: f64,
    /// 99th-percentile request latency, seconds
    pub p99_secs: f64,
    /// mean real rows per dispatched sweep (coalescing effectiveness)
    pub mean_batch_rows: f64,
    /// forward-workspace (re)allocations summed over the fleet — flat at
    /// `sessions` once warm (the steady-state zero-allocation invariant)
    pub forward_allocs: u64,
    /// fleet execution stats: summed throughput (`merge_sum`) plus the
    /// admission arbiter's lease counters
    pub exec: ExecStats,
}

impl ServeReport {
    /// JSON rendering for `pnode serve --json` and machine validation.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("sessions", Json::num(self.sessions as f64)),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("requests_per_sec", Json::num(self.requests_per_sec)),
            ("latency_p50_secs", Json::num(self.p50_secs)),
            ("latency_p99_secs", Json::num(self.p99_secs)),
            ("mean_batch_rows", Json::num(self.mean_batch_rows)),
            ("forward_allocs", Json::num(self.forward_allocs as f64)),
            ("lease_waits", Json::num(self.exec.lease_waits as f64)),
            ("lease_denied_bytes", Json::num(self.exec.lease_denied_bytes as f64)),
        ])
    }
}

/// Nearest-rank quantile over an ascending-sorted sample set; `0.0` on an
/// empty set (a pool that served nothing has no latency, not an infinite
/// one).
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).ceil() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_use_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(quantile(&xs, 0.5), 50.0);
        assert_eq!(quantile(&xs, 0.99), 99.0);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
        assert_eq!(quantile(&[7.0], 0.99), 7.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }

    #[test]
    fn config_validation_rejects_degenerate_knobs() {
        assert!(ServeConfig::default().validate().is_ok());
        assert!(ServeConfig { sessions: 0, ..Default::default() }.validate().is_err());
        assert!(ServeConfig { max_batch: 0, ..Default::default() }.validate().is_err());
        assert!(ServeConfig { max_delay_secs: f64::NAN, ..Default::default() }
            .validate()
            .is_err());
        assert!(ServeConfig { max_delay_secs: -1.0, ..Default::default() }
            .validate()
            .is_err());
    }
}
