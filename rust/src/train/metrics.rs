//! Metric accumulation for training runs (loss curves, NFE, wall-clock).

use std::time::Instant;

/// One epoch/iteration record.
#[derive(Clone, Debug)]
pub struct LogRow {
    pub step: usize,
    pub loss: f64,
    pub accuracy: Option<f64>,
    pub grad_norm: f64,
    pub nfe_forward: u64,
    pub nfe_backward: u64,
    /// wall-clock seconds since the previous `push` (or since the last
    /// `reset_clock`/`new` for the first row)
    pub wall_delta_secs: f64,
    /// cumulative sum of the per-push deltas.  Deliberately NOT "elapsed
    /// since log construction": that measurement silently absorbed any
    /// warmup/setup phase between construction and the first push into
    /// every row, over-reporting all of them.
    pub wall_secs: f64,
}

/// Append-only training log with CSV/JSON export.
#[derive(Debug, Default)]
pub struct TrainLog {
    pub rows: Vec<LogRow>,
    last_push: Option<Instant>,
    cum_secs: f64,
}

impl TrainLog {
    pub fn new() -> Self {
        TrainLog { rows: Vec::new(), last_push: Some(Instant::now()), cum_secs: 0.0 }
    }

    /// Restart the per-push clock — call after a warmup/setup phase so
    /// the first row's delta measures training work only.
    pub fn reset_clock(&mut self) {
        self.last_push = Some(Instant::now());
    }

    pub fn push(
        &mut self,
        step: usize,
        loss: f64,
        accuracy: Option<f64>,
        grad_norm: f64,
        nfe_forward: u64,
        nfe_backward: u64,
    ) {
        let delta = self.last_push.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        self.last_push = Some(Instant::now());
        self.cum_secs += delta;
        self.rows.push(LogRow {
            step,
            loss,
            accuracy,
            grad_norm,
            nfe_forward,
            nfe_backward,
            wall_delta_secs: delta,
            wall_secs: self.cum_secs,
        });
    }

    pub fn last(&self) -> Option<&LogRow> {
        self.rows.last()
    }

    pub fn best_loss(&self) -> f64 {
        self.rows.iter().map(|r| r.loss).fold(f64::INFINITY, f64::min)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "step,loss,accuracy,grad_norm,nfe_forward,nfe_backward,wall_delta_secs,wall_secs\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "{},{},{},{},{},{},{:.4},{:.3}\n",
                r.step,
                r.loss,
                r.accuracy.map(|a| a.to_string()).unwrap_or_default(),
                r.grad_norm,
                r.nfe_forward,
                r.nfe_backward,
                r.wall_delta_secs,
                r.wall_secs
            ));
        }
        s
    }
}

/// Gradient statistics across a run (explosion detection for Fig. 5).
#[derive(Clone, Debug, Default)]
pub struct GradStats {
    pub max_norm: f64,
    pub exploded: bool,
}

impl GradStats {
    pub fn observe(&mut self, norm: f64, explode_threshold: f64) {
        self.max_norm = self.max_norm.max(norm);
        if !norm.is_finite() || norm > explode_threshold {
            self.exploded = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_and_export() {
        let mut log = TrainLog::new();
        log.push(0, 1.0, Some(0.1), 0.5, 10, 10);
        log.push(1, 0.5, Some(0.6), 0.4, 10, 10);
        assert_eq!(log.best_loss(), 0.5);
        assert_eq!(log.last().unwrap().step, 1);
        let csv = log.to_csv();
        assert!(csv.lines().count() == 3);
        assert!(csv.contains("0.5"));
        assert!(csv.starts_with("step,"), "{csv}");
        assert!(csv.contains("wall_delta_secs,wall_secs"), "{csv}");
    }

    #[test]
    fn wall_clock_is_per_push_deltas_not_elapsed_since_construction() {
        let mut log = TrainLog::new();
        // emulate a warmup phase between construction and the first push
        std::thread::sleep(std::time::Duration::from_millis(30));
        log.reset_clock();
        log.push(0, 1.0, None, 0.5, 1, 1);
        log.push(1, 0.9, None, 0.5, 1, 1);
        let (r0, r1) = (&log.rows[0], &log.rows[1]);
        assert!(
            r0.wall_delta_secs < 0.025,
            "warmup must not leak into the first row: {}",
            r0.wall_delta_secs
        );
        assert!(r1.wall_secs >= r1.wall_delta_secs);
        let sum = r0.wall_delta_secs + r1.wall_delta_secs;
        assert!(
            (sum - r1.wall_secs).abs() < 1e-9,
            "cumulative column is the sum of deltas: {sum} vs {}",
            r1.wall_secs
        );
        assert!(r1.wall_secs >= r0.wall_secs, "cumulative is monotone");
    }

    #[test]
    fn explosion_detection() {
        let mut g = GradStats::default();
        g.observe(1.0, 1e3);
        assert!(!g.exploded);
        g.observe(f64::NAN, 1e3);
        assert!(g.exploded);
        let mut h = GradStats::default();
        h.observe(1e6, 1e3);
        assert!(h.exploded);
    }
}
