//! Metric accumulation for training runs (loss curves, NFE, wall-clock).

use std::time::Instant;

/// One epoch/iteration record.
#[derive(Clone, Debug)]
pub struct LogRow {
    pub step: usize,
    pub loss: f64,
    pub accuracy: Option<f64>,
    pub grad_norm: f64,
    pub nfe_forward: u64,
    pub nfe_backward: u64,
    pub wall_secs: f64,
}

/// Append-only training log with CSV/JSON export.
#[derive(Debug, Default)]
pub struct TrainLog {
    pub rows: Vec<LogRow>,
    started: Option<Instant>,
}

impl TrainLog {
    pub fn new() -> Self {
        TrainLog { rows: Vec::new(), started: Some(Instant::now()) }
    }

    pub fn push(
        &mut self,
        step: usize,
        loss: f64,
        accuracy: Option<f64>,
        grad_norm: f64,
        nfe_forward: u64,
        nfe_backward: u64,
    ) {
        let wall = self.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        self.rows.push(LogRow {
            step,
            loss,
            accuracy,
            grad_norm,
            nfe_forward,
            nfe_backward,
            wall_secs: wall,
        });
    }

    pub fn last(&self) -> Option<&LogRow> {
        self.rows.last()
    }

    pub fn best_loss(&self) -> f64 {
        self.rows.iter().map(|r| r.loss).fold(f64::INFINITY, f64::min)
    }

    pub fn to_csv(&self) -> String {
        let mut s =
            String::from("step,loss,accuracy,grad_norm,nfe_forward,nfe_backward,wall_secs\n");
        for r in &self.rows {
            s.push_str(&format!(
                "{},{},{},{},{},{},{:.3}\n",
                r.step,
                r.loss,
                r.accuracy.map(|a| a.to_string()).unwrap_or_default(),
                r.grad_norm,
                r.nfe_forward,
                r.nfe_backward,
                r.wall_secs
            ));
        }
        s
    }
}

/// Gradient statistics across a run (explosion detection for Fig. 5).
#[derive(Clone, Debug, Default)]
pub struct GradStats {
    pub max_norm: f64,
    pub exploded: bool,
}

impl GradStats {
    pub fn observe(&mut self, norm: f64, explode_threshold: f64) {
        self.max_norm = self.max_norm.max(norm);
        if !norm.is_finite() || norm > explode_threshold {
            self.exploded = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_and_export() {
        let mut log = TrainLog::new();
        log.push(0, 1.0, Some(0.1), 0.5, 10, 10);
        log.push(1, 0.5, Some(0.6), 0.4, 10, 10);
        assert_eq!(log.best_loss(), 0.5);
        assert_eq!(log.last().unwrap().step, 1);
        let csv = log.to_csv();
        assert!(csv.lines().count() == 3);
        assert!(csv.contains("0.5"));
    }

    #[test]
    fn explosion_detection() {
        let mut g = GradStats::default();
        g.observe(1.0, 1e3);
        assert!(!g.exploded);
        g.observe(f64::NAN, 1e3);
        assert!(g.exploded);
        let mut h = GradStats::default();
        h.observe(1e6, 1e3);
        assert!(h.exploded);
    }
}
