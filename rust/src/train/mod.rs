//! Training-loop utilities: metrics accumulation and gradient norms.

pub mod metrics;

pub use metrics::{GradStats, TrainLog};

/// Global L2 norm of a gradient vector (the paper's Fig. 5 plots this to
/// show the explicit-method explosion on stiff dynamics).
pub fn grad_norm(grad: &[f32]) -> f64 {
    crate::tensor::nrm2(grad)
}

/// Clip a gradient in place to `max_norm`; returns the pre-clip norm.
pub fn clip_grad_norm(grad: &mut [f32], max_norm: f64) -> f64 {
    let n = grad_norm(grad);
    if n > max_norm && n > 0.0 {
        let s = (max_norm / n) as f32;
        for g in grad.iter_mut() {
            *g *= s;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    #[test]
    fn clip_caps_norm() {
        let mut g = vec![3.0f32, 4.0];
        let pre = super::clip_grad_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-9);
        assert!((super::grad_norm(&g) - 1.0).abs() < 1e-6);
        // under the cap: untouched
        let mut h = vec![0.3f32, 0.4];
        super::clip_grad_norm(&mut h, 1.0);
        assert_eq!(h, vec![0.3, 0.4]);
    }
}
