//! Serving throughput at the paper's classifier shape (DESIGN.md §15):
//! the coalescing session pool vs a sequential single-request loop over
//! the same warm session, both on the allocation-free
//! `Session::forward_into` path.
//!
//! Results land in `BENCH_serve.json` at the repo root — a perf
//! *trajectory* keyed (name, build tag) exactly like `BENCH_micro.json` —
//! and as `ExperimentRow`s under `target/bench_results/serve_throughput.json`
//! with the serve columns (`requests_per_sec`, `latency_p50_secs`,
//! `latency_p99_secs`) filled.
//!
//! Flags: `--smoke` shrinks the request counts for CI and turns the run
//! into a hard gate:
//!   * coalesced serving must beat the unbatched loop by >= 1.5x,
//!   * the latency tail must be finite (p99 >= p50 > 0),
//!   * steady-state serving must not allocate (forward_allocs flat
//!     across the measured waves),
//!   * every served result must be bitwise identical to an isolated run.

use pnode::api::{RunSpec, Session, SolverBuilder};
use pnode::coordinator::{ExperimentRow, Runner};
use pnode::nn::module::ArchSpec;
use pnode::nn::Act;
use pnode::ode::rhs::OdeRhs;
use pnode::serve::{ServeConfig, ServePool, Ticket};
use pnode::util::json::Json;
use pnode::util::rng::Rng;

/// clf_d64 shape: 64 channels through concat-time MLP [168, 168], ReLU.
const D: usize = 64;

fn clf_spec() -> RunSpec {
    SolverBuilder::new()
        .scheme_str("rk4")
        .uniform(8)
        .arch(ArchSpec::ConcatMlp { hidden: vec![168, 168], act: Act::Relu })
        .build()
        .expect("clf_d64 serve spec")
}

fn requests(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut u0 = vec![0.0f32; D];
            rng.fill_normal(&mut u0);
            u0
        })
        .collect()
}

/// Sequential baseline: one warm session, one request per forward sweep.
fn run_unbatched(spec: &RunSpec, theta: &[f32], reqs: &[Vec<f32>]) -> f64 {
    let rhs = spec.make_rhs(D, 1, theta.to_vec()).expect("batch-1 rhs");
    let mut session = Session::new(spec.clone()).expect("session");
    let mut out = vec![0.0f32; D];
    // warm the workspace so the loop measures steady state
    session.forward_into(&rhs, &reqs[0], &mut out);
    let sw = pnode::obs::stopwatch();
    for u0 in reqs {
        session.forward_into(&rhs, u0, &mut out);
    }
    let secs = sw.elapsed_secs();
    reqs.len() as f64 / secs.max(1e-12)
}

/// Drive one pool configuration with `waves` bursts of `burst` requests
/// and return its final report (the pool is shut down).
fn run_pool(
    spec: &RunSpec,
    theta: &[f32],
    cfg: ServeConfig,
    reqs: &[Vec<f32>],
    burst: usize,
) -> pnode::serve::ServeReport {
    let theta_owned = theta.to_vec();
    let spec_rhs = spec.clone();
    let pool = ServePool::new(spec, D, cfg, move |rows| {
        Box::new(spec_rhs.make_rhs(D, rows, theta_owned.clone()).expect("pool rhs"))
            as Box<dyn OdeRhs + Send>
    })
    .expect("serve pool");
    for wave in reqs.chunks(burst) {
        let tickets: Vec<Ticket> =
            wave.iter().map(|u0| pool.submit(u0.clone()).expect("submit")).collect();
        for t in tickets {
            let _ = t.wait();
        }
    }
    pool.shutdown()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n_requests = if smoke { 96 } else { 512 };

    let spec = clf_spec();
    let mut rng = Rng::new(42);
    let theta = spec.init_theta(&mut rng, D).expect("theta");
    let reqs = requests(n_requests, 43);

    // ---- bitwise scatter contract: served == isolated, spot-checked up
    // front so a perf regression never masks a correctness one
    {
        let cfg = ServeConfig { sessions: 2, max_batch: 8, ..Default::default() };
        let theta_owned = theta.clone();
        let spec_rhs = spec.clone();
        let pool = ServePool::new(&spec, D, cfg, move |rows| {
            Box::new(spec_rhs.make_rhs(D, rows, theta_owned.clone()).expect("pool rhs"))
                as Box<dyn OdeRhs + Send>
        })
        .expect("serve pool");
        let probe: Vec<Ticket> =
            reqs[..16].iter().map(|u0| pool.submit(u0.clone()).expect("submit")).collect();
        let served: Vec<Vec<f32>> = probe.into_iter().map(Ticket::wait).collect();
        let _ = pool.shutdown();
        let rhs1 = spec.make_rhs(D, 1, theta.clone()).expect("batch-1 rhs");
        let mut isolated = Session::new(spec.clone()).expect("session");
        let mut out = vec![0.0f32; D];
        for (u0, got) in reqs[..16].iter().zip(&served) {
            isolated.forward_into(&rhs1, u0, &mut out);
            assert_eq!(&out, got, "served result must be bitwise = isolated run");
        }
        println!("scatter contract: 16/16 served results bitwise = isolated runs");
    }

    // ---- unbatched baseline ----------------------------------------
    let unbatched_rps = run_unbatched(&spec, &theta, &reqs);
    println!("unbatched  clf_d64        : {unbatched_rps:10.1} req/s");

    // ---- pool configurations ----------------------------------------
    let mut runner = Runner::new("serve_throughput");
    let mut bench_entries: Vec<(String, pnode::serve::ServeReport)> = Vec::new();
    let configs: &[(usize, usize)] = if smoke {
        &[(1, 16)]
    } else {
        &[(1, 4), (1, 16), (2, 16), (4, 16)]
    };
    for &(sessions, max_batch) in configs {
        let cfg = ServeConfig { sessions, max_batch, ..Default::default() };
        let sw = pnode::obs::stopwatch();
        let rep = run_pool(&spec, &theta, cfg, &reqs, max_batch);
        let wall = sw.elapsed_secs();
        let name = format!("serve clf_d64 s{sessions} b{max_batch}");
        println!(
            "{name:<26}: {:10.1} req/s  p50 {:.3} ms  p99 {:.3} ms  ({:.1} rows/sweep)",
            rep.requests_per_sec,
            rep.p50_secs * 1e3,
            rep.p99_secs * 1e3,
            rep.mean_batch_rows
        );
        runner
            .rows
            .push(ExperimentRow::from_serve_report("serve_throughput", "clf_d64", &spec, &rep, wall));
        bench_entries.push((name, rep));

        if smoke && sessions == 1 && max_batch == 16 {
            let speedup = rep.requests_per_sec / unbatched_rps.max(1e-12);
            println!("  coalescing speedup over unbatched: {speedup:.2}x");
            assert!(
                speedup >= 1.5,
                "perf gate: coalesced serving ({:.1} req/s) must be >= 1.5x the unbatched \
                 loop ({unbatched_rps:.1} req/s), got {speedup:.2}x",
                rep.requests_per_sec
            );
            assert!(
                rep.p99_secs.is_finite() && rep.p99_secs >= rep.p50_secs && rep.p50_secs > 0.0,
                "latency gate: p50 {} p99 {}",
                rep.p50_secs,
                rep.p99_secs
            );
            assert_eq!(
                rep.forward_allocs, sessions as u64,
                "alloc gate: steady-state serving must not reallocate ({rep:?})"
            );
            println!("  smoke gates passed (speedup, finite tail, zero steady-state allocs)");
        }
    }

    match runner.save() {
        Ok(p) => println!("rows -> {}", p.display()),
        Err(e) => println!("(could not write rows: {e})"),
    }

    // BENCH_serve.json is a perf *trajectory* like BENCH_micro.json:
    // entries are keyed (name, build tag) and accumulate across PRs;
    // re-running the same build replaces its own entries
    let build = pnode::obs::build_tag();
    let path = "BENCH_serve.json";
    let mut entries: Vec<Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| pnode::util::json::parse(&t).ok())
        .and_then(|j| j.as_arr().map(|a| a.to_vec()))
        .unwrap_or_default();
    let fresh: Vec<&str> = bench_entries.iter().map(|(n, _)| n.as_str()).collect();
    entries.retain(|e| {
        let same_build = e.get("build").and_then(Json::as_str) == Some(build.as_str());
        let name = e.get("name").and_then(Json::as_str).unwrap_or("");
        !(same_build && fresh.contains(&name))
    });
    for (name, rep) in &bench_entries {
        let mut kv = vec![
            ("build".to_string(), Json::str(build.clone())),
            ("name".to_string(), Json::str(name.clone())),
        ];
        if let Json::Obj(obj) = rep.to_json() {
            kv.extend(obj);
        }
        entries.push(Json::Obj(kv));
    }
    let total = entries.len();
    match std::fs::write(path, Json::Arr(entries).to_string_pretty()) {
        Ok(()) => println!(
            "appended {} entries (build {build}) to {path} ({total} total)",
            bench_entries.len()
        ),
        Err(e) => println!("(could not write {path}: {e})"),
    }
}
