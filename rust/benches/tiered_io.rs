//! Tiered checkpoint-storage I/O bench: gradient wall-time and tier
//! traffic across RAM budgets (all-resident → heavy spill), f32 vs f16
//! cold payloads, and in-memory vs tiered at equal placement.  Rows land
//! in `target/bench_results/tiered_io.json` with the spill/prefetch
//! counters per row.  `PNODE_BENCH_FULL=1` widens the sweep.

use pnode::api::{Session, SolverBuilder};
use pnode::bench::Table;
use pnode::checkpoint::CheckpointPolicy;
use pnode::coordinator::Runner;
use pnode::nn::Act;
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::util::rng::Rng;

fn main() {
    let full = std::env::var("PNODE_BENCH_FULL").is_ok();
    let nt = if full { 4096 } else { 512 };

    let dims = vec![33, 64, 32];
    let mut rng = Rng::new(11);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let rhs = ModuleRhs::mlp(dims, Act::Tanh, true, 16, theta);
    let mut u0 = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut u0);
    let lambda0 = vec![1.0f32; rhs.state_len()];
    let spec_of = |policy: CheckpointPolicy| {
        SolverBuilder::new()
            .policy(policy)
            .scheme_str("dopri5")
            .uniform(nt)
            .build()
            .expect("valid tiered-io spec")
    };

    let spill_dir =
        std::env::temp_dir().join(format!("pnode-tiered-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_dir);

    // footprint of the all-resident run, to express budgets as fractions
    let footprint = {
        let mut session =
            Session::new(spec_of(CheckpointPolicy::All)).expect("valid spec");
        session.grad(&rhs, &u0, &lambda0).report.ckpt_bytes
    };
    println!(
        "all-resident checkpoint footprint: {} (N_t = {nt}, Dopri5)",
        pnode::util::human_bytes(footprint)
    );

    let mut runner = Runner::new("tiered_io");
    let mut table = Table::new(
        "Tiered checkpoint I/O — budget sweep",
        &["config", "budget", "time/grad (s)", "peak RAM", "cold written", "spills", "pf hits", "sync reads"],
    );

    let mut job = |label: &str, policy: CheckpointPolicy, budget_label: &str| {
        let spec = spec_of(policy);
        let row = runner.run_spec_job("mlp_33_64_32", &spec, 0, || {
            let mut session = Session::new(spec.clone()).expect("spec validated at build");
            session.grad(&rhs, &u0, &lambda0).report
        });
        table.row(vec![
            label.into(),
            budget_label.into(),
            format!("{:.4}", row.time_secs),
            pnode::util::human_bytes(row.ckpt_hot_bytes),
            pnode::util::human_bytes(row.ckpt_cold_bytes),
            row.spill_count.to_string(),
            row.prefetch_hits.to_string(),
            row.cold_reads.to_string(),
        ]);
    };

    job("in-memory", CheckpointPolicy::All, "∞");
    let fractions: &[(u64, &str)] = if full {
        &[(2, "1/2"), (4, "1/4"), (8, "1/8"), (16, "1/16"), (64, "1/64")]
    } else {
        &[(2, "1/2"), (4, "1/4"), (16, "1/16")]
    };
    let dir = spill_dir.to_string_lossy().into_owned();
    for &(div, label) in fractions {
        for f16 in [false, true] {
            let policy = CheckpointPolicy::Tiered {
                budget_bytes: (footprint / div).max(1),
                dir: dir.clone(),
                compress_f16: f16,
                inner: Box::new(CheckpointPolicy::All),
            };
            let name = if f16 { "tiered+f16" } else { "tiered" };
            job(name, policy, label);
        }
    }
    // composition: Revolve placement under a byte budget
    job(
        "tiered+binomial:32",
        CheckpointPolicy::Tiered {
            budget_bytes: (footprint / 16).max(1),
            dir: dir.clone(),
            compress_f16: false,
            inner: Box::new(CheckpointPolicy::Binomial { n_checkpoints: 32 }),
        },
        "1/16",
    );

    table.print();
    let path = runner.save().expect("save results");
    println!("\nrows saved to {path:?} (total {:.1}s)", runner.elapsed_secs());
    println!(
        "Expected shape: time/grad degrades only mildly as the budget shrinks\n\
         (reads overlap recomputation via the reverse-order prefetcher);\n\
         f16 halves cold bytes at ~1e-3 relative checkpoint error."
    );
    let _ = std::fs::remove_dir_all(&spill_dir);
}
