//! Fig. 2 regeneration (shape): training/test accuracy of the ODE-block
//! classifier with discrete vs continuous adjoint across schemes, with
//! ReLU dynamics (the irreversibility that breaks the continuous adjoint).
//! Also prints the Prop.-1 discrepancy decay table (`--prop1` content).
//! All gradient runs are facade specs/sessions.

use pnode::api::{Session, SolverBuilder};
use pnode::bench::Table;
use pnode::data::spiral::SpiralDataset;
use pnode::nn::{Act, Adam, Optimizer};
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau::Scheme;
use pnode::tasks::ClassificationTask;
use pnode::testing::prop;
use pnode::util::rng::Rng;

const D: usize = 16;
const B: usize = 64;

fn train_once(method: &str, scheme: Scheme, steps: usize) -> (f64, f64) {
    let mut rng = Rng::new(77);
    let dims = vec![D + 1, 32, D];
    let p = pnode::nn::param_count(&dims);
    let dims_i = dims.clone();
    let spec = SolverBuilder::new()
        .method_str(method)
        .scheme(scheme)
        .uniform(1) // paper Fig. 2: one time step
        .build()
        .unwrap_or_else(|e| panic!("{method}: {e}"));
    let mut task = ClassificationTask::new(&mut rng, 2, &spec, p, D, 4, move |r| {
        pnode::nn::init::kaiming_uniform(r, &dims_i, 1.0)
    });
    let mut rhs = ModuleRhs::mlp(dims, Act::Relu, true, B, task.block_theta(0).to_vec());
    let ds = SpiralDataset::generate(&mut rng, 300, 4, D);
    let (train, test) = ds.split(0.9);
    let mut opt = Adam::new(task.theta.len(), 3e-3);
    let mut x = vec![0.0f32; B * D];
    let mut y = vec![0usize; B];
    let mut train_acc = 0.0;
    for it in 0..steps {
        train.fill_batch(it * B, B, &mut x, &mut y);
        let res = task.grad_step(&mut rhs, B, &x, &y, 0.05);
        train_acc = res.accuracy;
        let g = res.grad;
        task.apply_grad(&mut opt as &mut dyn Optimizer, &g);
    }
    let mut xt = vec![0.0f32; B * D];
    let mut yt = vec![0usize; B];
    test.fill_batch(0, B, &mut xt, &mut yt);
    let (_, test_acc) = task.evaluate(&mut rhs, B, &xt, &yt);
    (train_acc, test_acc)
}

fn main() {
    let steps = if std::env::var("PNODE_BENCH_FULL").is_ok() { 250 } else { 80 };

    let mut table = Table::new(
        "Fig. 2 — accuracy with one time step, ReLU dynamics",
        &["scheme", "method", "train acc", "test acc"],
    );
    for scheme in [Scheme::Euler, Scheme::Midpoint, Scheme::Rk4, Scheme::Dopri5] {
        for method in ["pnode", "cont"] {
            let (tr, te) = train_once(method, scheme, steps);
            table.row(vec![
                scheme.name().into(),
                method.into(),
                format!("{tr:.3}"),
                format!("{te:.3}"),
            ]);
        }
    }
    table.print();

    // Prop. 1: ||λ_cont − λ_disc|| decays ~O(h) accumulated
    let mut t2 = Table::new(
        "Prop. 1 — continuous-vs-discrete adjoint discrepancy (Euler)",
        &["N_t", "rel-l2(λ_cont, λ_disc)"],
    );
    let dims = vec![5, 12, 4];
    let mut rng = Rng::new(99);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.5);
    let rhs = ModuleRhs::mlp(dims, Act::Tanh, true, 2, theta);
    let u0 = prop::vec_uniform(&mut rng, rhs.state_len(), 0.5);
    let w = prop::vec_uniform(&mut rng, rhs.state_len(), 1.0);
    let mut prev = f64::INFINITY;
    for nt in [4usize, 8, 16, 32, 64] {
        let lambda0_of = |method: &str| -> Vec<f32> {
            let mut session: Session = SolverBuilder::new()
                .method_str(method)
                .scheme(Scheme::Euler)
                .uniform(nt)
                .session()
                .unwrap_or_else(|e| panic!("{method}: {e}"));
            let _ = session.grad(&rhs, &u0, &w);
            session.lambda0().to_vec()
        };
        let l_d = lambda0_of("pnode");
        let l_c = lambda0_of("cont");
        let gap = pnode::testing::rel_l2(&l_c, &l_d);
        t2.row(vec![nt.to_string(), format!("{gap:.3e}")]);
        assert!(gap < prev * 1.05, "discrepancy must decay");
        prev = gap;
    }
    t2.print();
    println!(
        "\nExpected shape: discrete adjoint (pnode) reaches higher accuracy\n\
         than the continuous adjoint with ReLU + low-accuracy schemes; the\n\
         Prop.-1 gap shrinks as h -> 0."
    );
}
