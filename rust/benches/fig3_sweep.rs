//! Fig. 3 regeneration: memory and time-per-epoch as functions of N_t for
//! (scheme × method), on the paper-sized classification model
//! (dims 65-168-168-64, batch 128).  Memory columns come from the Table-2
//! model (V100 semantics, +0.4 GB CUDA constant); time is measured on this
//! testbed.  `PNODE_BENCH_FULL=1` widens the sweep.

use pnode::api::{Session, SolverBuilder};
use pnode::bench::Table;
use pnode::coordinator::Runner;
use pnode::methods::MemModel;
use pnode::nn::Act;
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau::Scheme;
use pnode::util::rng::Rng;

fn main() {
    let full = std::env::var("PNODE_BENCH_FULL").is_ok();
    let schemes: Vec<Scheme> = if full {
        vec![Scheme::Euler, Scheme::Midpoint, Scheme::Bosh3, Scheme::Rk4, Scheme::Dopri5]
    } else {
        vec![Scheme::Euler, Scheme::Rk4, Scheme::Dopri5]
    };
    let nts: Vec<usize> = if full { vec![1, 3, 5, 7, 9, 11] } else { vec![2, 5, 11] };
    let methods = ["naive", "cont", "anode", "aca", "pnode", "pnode2"];

    const D: usize = 64;
    const B: usize = 128;
    let dims = vec![D + 1, 168, 168, D];
    let mut rng = Rng::new(3);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let rhs = ModuleRhs::mlp(dims.clone(), Act::Relu, true, B, theta);
    let mut u0 = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut u0);
    let lambda0 = vec![1.0f32; rhs.state_len()];
    let nb = 4u64; // paper: 4 ODE blocks

    let mut runner = Runner::new("fig3_sweep");
    let mut table = Table::new(
        "Fig. 3 — memory & time vs N_t (4 blocks modeled, 1 block measured)",
        &["scheme", "N_t", "method", "model GB", "time/grad (s)", "NFE f/b"],
    );

    for &scheme in &schemes {
        let s = scheme.tableau().s as u64;
        for &nt in &nts {
            // problem sizes measured off the module graph itself (summed
            // per-module activation bytes — Table-2 semantics)
            let mm = MemModel::for_rhs(&rhs, s, nt as u64, nb);
            for method in methods {
                let model_mem = mm.by_method(method).unwrap();
                let spec = SolverBuilder::new()
                    .method_str(method)
                    .scheme(scheme)
                    .uniform(nt)
                    .build()
                    .unwrap_or_else(|e| panic!("{method}: {e}"));
                let row = runner.run_spec_job("spiral_clf", &spec, model_mem, || {
                    let mut session =
                        Session::new(spec.clone()).expect("spec validated at build");
                    session.grad(&rhs, &u0, &lambda0).report
                });
                let oom = model_mem > 32 * (1u64 << 30);
                table.row(vec![
                    scheme.name().into(),
                    nt.to_string(),
                    method.into(),
                    if oom {
                        format!("OOM ({:.1})", MemModel::gb(model_mem))
                    } else {
                        format!("{:.3}", MemModel::gb(model_mem))
                    },
                    format!("{:.3}", row.time_secs),
                    format!("{}/{}", row.nfe_forward, row.nfe_backward),
                ]);
            }
        }
    }
    table.print();
    let path = runner.save().expect("save results");
    println!("\nrows saved to {path:?} (total {:.1}s)", runner.elapsed_secs());
    println!(
        "Expected shape: PNODE has the slowest memory growth among\n\
         reverse-accurate methods and the fastest time; naive grows steepest."
    );
}
