//! Tables 3–7 regeneration: CNF (FFJORD) performance statistics — NFE-F,
//! NFE-B, time per iteration, and modeled GPU memory — for each scheme
//! (Euler, Midpoint, Bosh3, RK4, Dopri5) × dataset surrogate (POWER,
//! MINIBOONE, BSDS300) × framework (naive, cont, anode, aca, pnode).
//!
//! Dynamics: the AOT `cnf_*` artifacts when available (`make artifacts`);
//! otherwise the XLA-free concatsquash module path
//! (`HutchinsonCnfRhs` over `ArchSpec::ConcatSquashMlp`, whose trace
//! adjoint is exact through the module system's second-order pass).
//! N_t values follow the paper (scaled down under the default quick mode —
//! set PNODE_BENCH_FULL=1 for the paper's step counts).

use pnode::api::{ArchSpec, Session, SolverBuilder};
use pnode::bench::Table;
use pnode::coordinator::Runner;
use pnode::data::tabular::TabularDataset;
use pnode::methods::MemModel;
use pnode::nn::Act;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::rhs_xla::XlaCnfRhs;
use pnode::ode::tableau::Scheme;
use pnode::runtime::{Client, Manifest, ModelArtifacts};
use pnode::tasks::HutchinsonCnfRhs;
use pnode::util::rng::Rng;

// paper N_t per (scheme, dataset): POWER / MINIBOONE / BSDS300
fn paper_nt(scheme: Scheme) -> [usize; 3] {
    match scheme {
        Scheme::Euler => [50, 20, 100],
        Scheme::Midpoint => [40, 16, 80],
        Scheme::Bosh3 => [30, 12, 60],
        Scheme::Rk4 => [20, 8, 40],
        Scheme::Dopri5 => [10, 4, 20],
        _ => [10, 10, 10],
    }
}

#[allow(clippy::too_many_arguments)]
fn bench_dataset(
    runner: &mut Runner,
    ds_name: &str,
    idx: usize,
    nb: u64,
    rhs: &dyn OdeRhs,
    x: &[f32],
    b: usize,
    d: usize,
    full: bool,
) {
    let schemes = [Scheme::Euler, Scheme::Midpoint, Scheme::Bosh3, Scheme::Rk4, Scheme::Dopri5];
    let methods = ["naive", "cont", "anode", "aca", "pnode"];
    let mut z0 = vec![0.0f32; rhs.state_len()];
    z0[..b * d].copy_from_slice(x);
    let lambda0 = vec![1.0f32; rhs.state_len()];

    let mut table = Table::new(
        &format!("Tables 3–7 — {ds_name} (d={d}, batch={b})"),
        &["scheme", "N_t", "framework", "NFE-F", "NFE-B", "time/iter (s)", "model GB"],
    );
    for &scheme in &schemes {
        let nt_paper = paper_nt(scheme)[idx];
        let nt = if full { nt_paper } else { (nt_paper / 4).max(2) };
        let s = scheme.tableau().s as u64;
        // problem sizes off the RHS itself: summed per-module activation
        // bytes for the module path, artifact accounting for XLA
        let mm = MemModel::for_rhs(rhs, s, nt as u64, nb);
        for method in methods {
            let model_mem = mm.by_method(method).unwrap();
            let spec = SolverBuilder::new()
                .method_str(method)
                .scheme(scheme)
                .uniform(nt)
                .build()
                .unwrap_or_else(|e| panic!("{method}: {e}"));
            let row = runner.run_spec_job(ds_name, &spec, model_mem, || {
                let mut session = Session::new(spec.clone()).expect("spec validated at build");
                session.grad(rhs, &z0, &lambda0).report
            });
            let oom = model_mem > 32 * (1u64 << 30);
            table.row(vec![
                scheme.name().into(),
                nt.to_string(),
                method.into(),
                (row.nfe_forward * nb).to_string(),
                (row.nfe_backward * nb).to_string(),
                format!("{:.3}", row.time_secs * nb as f64),
                if oom {
                    format!("OOM ({:.1})", MemModel::gb(model_mem))
                } else {
                    format!("{:.3}", MemModel::gb(model_mem))
                },
            ]);
        }
    }
    table.print();
}

fn main() {
    let full = std::env::var("PNODE_BENCH_FULL").is_ok();
    let datasets = [
        ("power", "cnf_power", 0usize),
        ("miniboone", "cnf_miniboone", 1),
        ("bsds300", "cnf_bsds300", 2),
    ];
    // paper: 5/1/2 flow steps; we model nb per dataset
    let nb_of = [5u64, 1, 2];

    let artifacts = Client::cpu().ok().and_then(|client| {
        Manifest::load_default().ok().map(|manifest| (client, manifest))
    });
    if artifacts.is_none() {
        eprintln!("artifacts not built: running the XLA-free concatsquash module path");
    }

    let mut runner = Runner::new("tables3_7_cnf");
    let mut rng = Rng::new(11);

    for (di, (ds_name, cfg_name, idx)) in datasets.iter().enumerate() {
        let data = TabularDataset::from_preset(&mut rng, ds_name).unwrap();
        if let Some((client, manifest)) = &artifacts {
            match ModelArtifacts::load(client, manifest, cfg_name) {
                Ok(arts) => {
                    let entry = arts.entry.clone();
                    let (b, d) = (entry.batch, entry.state_dim);
                    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &entry.dims, 0.5);
                    let mut rhs = XlaCnfRhs::new(arts, theta).expect("cnf rhs");
                    let mut x = vec![0.0f32; b * d];
                    data.fill_batch(0, b, &mut x);
                    let mut eps = vec![0.0f32; b * d];
                    rng.fill_rademacher(&mut eps);
                    rhs.set_eps(&eps);
                    bench_dataset(&mut runner, ds_name, *idx, nb_of[di], &rhs, &x, b, d, full);
                    continue;
                }
                Err(e) => eprintln!("{ds_name}: artifacts unusable ({e}); module path"),
            }
        }
        // XLA-free path: concatsquash dynamics at the dataset's dim
        let d = data.dim;
        let b = if full { 128 } else { 32 };
        let arch = ArchSpec::ConcatSquashMlp { hidden: vec![2 * d], act: Act::Tanh };
        let theta = arch.init(&mut rng, d);
        let rhs = HutchinsonCnfRhs::new(&arch, b, d, theta, &mut rng);
        let mut x = vec![0.0f32; b * d];
        data.fill_batch(0, b, &mut x);
        bench_dataset(&mut runner, ds_name, *idx, nb_of[di], &rhs, &x, b, d, full);
    }
    let path = runner.save().expect("save");
    println!("\nrows saved to {path:?} (total {:.1}s)", runner.elapsed_secs());
    println!(
        "Expected shape (paper Tables 3–7): ACA NFE-B ≈ 2× PNODE's; PNODE\n\
         fastest among reverse-accurate; naive/anode OOM on BSDS300 at the\n\
         paper's scale; PNODE's modeled memory lowest among reverse-accurate."
    );
}
