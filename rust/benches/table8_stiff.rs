//! Table 8 + Fig. 5 regeneration: Crank–Nicolson vs adaptive Dopri5 on the
//! Robertson stiff system — NFE-F/NFE-B, time per iteration, accepted vs
//! rejected step counts (the adaptive grid now runs through the unified
//! checkpointed adjoint driver), gradient norms (explosion), and Fig. 4's
//! raw-vs-scaled data comparison.

use pnode::bench::Table;
use pnode::data::robertson::RobertsonData;
use pnode::nn::{Act, AdamW, Optimizer};
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau::Scheme;
use pnode::tasks::StiffTask;
use pnode::train::GradStats;
use pnode::util::rng::Rng;
use pnode::util::stats::Stream;

struct Outcome {
    mae: f64,
    nfe_f: f64,
    nfe_b: f64,
    accepted: f64,
    rejected: f64,
    secs: f64,
    max_grad: f64,
    exploded: bool,
}

fn train(task: &StiffTask, mode: &str, epochs: usize) -> Outcome {
    let dims = vec![3, 24, 24, 24, 3];
    let mut rng = Rng::new(5);
    let mut theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 0.05);
    let mut rhs = ModuleRhs::mlp(dims, Act::Gelu, false, 1, theta.clone());
    let mut opt = AdamW::new(theta.len(), 5e-3, 1e-4);
    let mut stats = GradStats::default();
    let (mut nfe_f, mut nfe_b) = (Stream::new(), Stream::new());
    let (mut accepted, mut rejected) = (Stream::new(), Stream::new());
    let mut secs = Stream::new();
    let mut mae = f64::NAN;
    for _ in 0..epochs {
        let t = std::time::Instant::now();
        let step = match mode {
            "cn" => task.grad_implicit(&rhs, Scheme::CrankNicolson),
            "beuler" => task.grad_implicit(&rhs, Scheme::BackwardEuler),
            _ => task.grad_explicit_adaptive(&rhs, 1e-6),
        };
        secs.push(t.elapsed().as_secs_f64());
        mae = step.loss;
        nfe_f.push(step.nfe_forward as f64);
        nfe_b.push(step.nfe_backward as f64);
        accepted.push(step.n_accepted as f64);
        rejected.push(step.n_rejected as f64);
        let gn = pnode::train::grad_norm(&step.grad);
        stats.observe(gn, 1e5);
        if !gn.is_finite() {
            break;
        }
        let mut g = step.grad;
        pnode::train::clip_grad_norm(&mut g, 50.0);
        opt.step(&mut theta, &g);
        rhs.set_params(&theta);
    }
    Outcome {
        mae,
        nfe_f: nfe_f.mean(),
        nfe_b: nfe_b.mean(),
        accepted: accepted.mean(),
        rejected: rejected.mean(),
        secs: secs.mean(),
        max_grad: stats.max_norm,
        exploded: stats.exploded,
    }
}

fn main() {
    let epochs = if std::env::var("PNODE_BENCH_FULL").is_ok() { 400 } else { 60 };

    // Fig. 4: scaled vs raw data
    let mut fig4 = Table::new(
        "Fig. 4 — effect of min–max scaling (CN, short training)",
        &["data", "final MAE", "note"],
    );
    for (label, scaled) in [("raw", false), ("scaled", true)] {
        let data = RobertsonData::generate(40, 6, scaled);
        let task = StiffTask::new(data, 2);
        let o = train(&task, "cn", epochs / 2);
        fig4.row(vec![
            label.into(),
            format!("{:.5}", o.mae),
            if scaled { "species comparable".into() } else { "u2 invisible in loss".to_string() },
        ]);
    }
    fig4.print();

    // Table 8 + Fig. 5
    let data = RobertsonData::generate(40, 6, true);
    let task = StiffTask::new(data, 2);
    let mut t8 = Table::new(
        "Table 8 / Fig. 5 — CN vs adaptive Dopri5 on Robertson",
        &[
            "integrator", "avg NFE-F", "avg NFE-B", "avg steps", "avg rejects",
            "time/iter (s)", "final MAE", "max |grad|", "exploded",
        ],
    );
    for mode in ["cn", "beuler", "dopri5"] {
        let o = train(&task, mode, epochs);
        t8.row(vec![
            mode.into(),
            format!("{:.0}", o.nfe_f),
            format!("{:.0}", o.nfe_b),
            format!("{:.0}", o.accepted),
            format!("{:.0}", o.rejected),
            format!("{:.3}", o.secs),
            format!("{:.5}", o.mae),
            format!("{:.2e}", o.max_grad),
            o.exploded.to_string(),
        ]);
    }
    t8.print();
    println!(
        "\nExpected shape (paper Table 8 / Fig. 5): implicit methods train\n\
         stably; the explicit adaptive method needs far more NFE as training\n\
         progresses (stiffness grows) and its gradient norms blow up."
    );
}
