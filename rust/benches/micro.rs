//! Hot-path microbenchmarks (the §Perf profile base): raw GEMM kernel
//! paths, ERK step, adjoint step, VJP through the pure-Rust MLP and
//! (if built) the XLA artifacts, GMRES iteration, checkpoint store ops.
//!
//! Besides the human-readable summaries, every result is appended to
//! `BENCH_micro.json` at the repo root (cargo runs benches from the
//! workspace root) so perf trends are machine-diffable across commits.
//!
//! Flags: `--smoke` shrinks iteration counts for CI and turns the
//! SIMD-vs-scalar comparison into a hard gate (the packed kernel must
//! not be slower than the legacy scalar loop at the paper shape).

use pnode::adjoint::discrete_erk::{adjoint_erk_step, AdjointErkWorkspace};
use pnode::bench::{bench_fn, BenchResult};
use pnode::linalg::gmres::{gmres, GmresOptions};
use pnode::nn::Act;
use pnode::ode::erk::{erk_step, ErkWorkspace};
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau;
use pnode::tensor::gemm::{self, KernelPath};
use pnode::util::rng::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (warm, iters) = if smoke { (1usize, 3usize) } else { (2, 10) };
    let (warm2, iters2) = if smoke { (1usize, 2usize) } else { (1, 5) };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut record = |r: BenchResult, results: &mut Vec<BenchResult>| {
        println!("{}", r.summary());
        results.push(r);
    };

    let mut rng = Rng::new(1);

    // ---- raw GEMM kernel paths at the paper's hot shape -------------
    // (B=128 rows through the 168-wide hidden layers; `_with` variants
    // so one process exercises both the scalar and SIMD paths despite
    // the one-time env dispatch)
    let simd_path = match gemm::kernel_path() {
        KernelPath::Scalar => KernelPath::Portable,
        p => p,
    };
    {
        let (m, k, n) = (128usize, 168usize, 168usize);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 83) as f32 * 0.013 - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 71) as f32 * 0.017 - 0.6).collect();
        let mut c = vec![0.0f32; m * n];
        let scalar = bench_fn("sgemm 128x168x168 scalar", warm, iters, || {
            gemm::sgemm_with(KernelPath::Scalar, m, k, n, &a, &b, &mut c, 0.0);
        });
        record(scalar.clone(), &mut results);
        let simd = bench_fn(
            &format!("sgemm 128x168x168 {}", simd_path.name()),
            warm,
            iters,
            || {
                gemm::sgemm_with(simd_path, m, k, n, &a, &b, &mut c, 0.0);
            },
        );
        record(simd.clone(), &mut results);
        let speedup = scalar.mean_secs / simd.mean_secs.max(1e-12);
        println!("  sgemm {} speedup over scalar: {speedup:.2}x", simd_path.name());
        if smoke {
            assert!(
                speedup >= 1.0,
                "perf gate: {} sgemm slower than scalar ({speedup:.2}x)",
                simd_path.name()
            );
        }
        // the adjoint's gW kernel (Aᵀ layout) at the same shape
        let at_a: Vec<f32> = (0..k * m).map(|i| (i % 59) as f32 * 0.011 - 0.3).collect();
        let at_b: Vec<f32> = (0..k * n).map(|i| (i % 67) as f32 * 0.019 - 0.7).collect();
        let mut at_c = vec![0.0f32; m * n];
        let r = bench_fn("sgemm_at 128x168x168 scalar", warm, iters, || {
            gemm::sgemm_at_with(KernelPath::Scalar, m, k, n, &at_a, &at_b, &mut at_c, 0.0);
        });
        record(r, &mut results);
        let r = bench_fn(
            &format!("sgemm_at 128x168x168 {}", simd_path.name()),
            warm,
            iters,
            || {
                gemm::sgemm_at_with(simd_path, m, k, n, &at_a, &at_b, &mut at_c, 0.0);
            },
        );
        record(r, &mut results);
    }
    // paper-scale RHS: 65-168-168-64, batch 128
    let dims = vec![65, 168, 168, 64];
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let rhs = ModuleRhs::mlp(dims, Act::Relu, true, 128, theta);
    let n = rhs.state_len();
    let mut u = vec![0.0f32; n];
    rng.fill_normal(&mut u);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v);
    let mut out = vec![0.0f32; n];
    let mut gt = vec![0.0f32; rhs.param_len()];

    let r = bench_fn("mlp.f (B=128, 65-168-168-64)", warm, iters, || {
        rhs.f(0.3, &u, &mut out);
    });
    record(r, &mut results);
    let r = bench_fn("mlp.vjp_both", warm, iters, || {
        rhs.vjp_both(0.3, &u, &v, &mut out, &mut gt);
    });
    record(r, &mut results);
    let r = bench_fn("mlp.jvp", warm, iters, || {
        rhs.jvp(0.3, &u, &v, &mut out);
    });
    record(r, &mut results);

    // ---- fused plan vs the pre-fusion per-module composition --------
    // Same GEMM path underneath; the delta is the Linear+Activation
    // epilogue fusion (one pass over each output row instead of three).
    {
        use pnode::nn::module::{Activation, Linear, Module, Sequential};
        let dims = [65usize, 168, 168, 64];
        let bsz = 128usize;
        let seq = Sequential::new(vec![
            Box::new(Linear::new(65, 168)) as Box<dyn Module>,
            Box::new(Activation::new(Act::Relu, 168)),
            Box::new(Linear::new(168, 168)),
            Box::new(Activation::new(Act::Relu, 168)),
            Box::new(Linear::new(168, 64)),
        ]);
        let theta2 = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
        let mut x = vec![0.0f32; bsz * dims[0]];
        rng.fill_normal(&mut x);
        let mut y = vec![0.0f32; bsz * dims[3]];
        let mut cache = vec![0.0f32; seq.cache_len(bsz)];
        let r = bench_fn("seq.forward fused (B=128, 65-168-168-64)", warm, iters, || {
            seq.forward(bsz, 0.3, &theta2, &x, &mut y, &mut cache);
        });
        record(r, &mut results);
        // hand-rolled replica of the pre-fusion per-child loop: GEMM,
        // then a bias sweep, then a cache copy + activation sweep
        let wmax = 168usize;
        let mut cur = vec![0.0f32; bsz * wmax];
        let mut nxt = vec![0.0f32; bsz * wmax];
        let r = bench_fn("seq.forward unfused baseline", warm, iters, || {
            let mut c_off = 0usize;
            let mut o = 0usize;
            cur[..bsz * dims[0]].copy_from_slice(&x);
            for l in 0..dims.len() - 1 {
                let (din, dout) = (dims[l], dims[l + 1]);
                let w = &theta2[o..o + din * dout];
                let b = &theta2[o + din * dout..o + din * dout + dout];
                o += din * dout + dout;
                cache[c_off..c_off + bsz * din].copy_from_slice(&cur[..bsz * din]);
                c_off += bsz * din;
                gemm::sgemm(bsz, din, dout, &cur[..bsz * din], w, &mut nxt[..bsz * dout], 0.0);
                for row in 0..bsz {
                    for j in 0..dout {
                        nxt[row * dout + j] += b[j];
                    }
                }
                if l + 1 < dims.len() - 1 {
                    cache[c_off..c_off + bsz * dout].copy_from_slice(&nxt[..bsz * dout]);
                    c_off += bsz * dout;
                    for vj in nxt[..bsz * dout].iter_mut() {
                        *vj = Act::Relu.apply(*vj);
                    }
                }
                std::mem::swap(&mut cur, &mut nxt);
            }
            y.copy_from_slice(&cur[..bsz * dims[3]]);
        });
        record(r, &mut results);
    }

    let tab = &tableau::DOPRI5;
    let mut ks: Vec<Vec<f32>> = (0..tab.s).map(|_| vec![0.0f32; n]).collect();
    let mut un = vec![0.0f32; n];
    let mut ews = ErkWorkspace::new(n);
    let r = bench_fn("erk_step dopri5", warm, iters, || {
        erk_step(tab, &rhs, 0.0, 0.1, &u, &mut ks, &mut un, &mut ews, None);
    });
    record(r, &mut results);

    let mut aws = AdjointErkWorkspace::new(tab.s, n);
    let mut lambda = v.clone();
    let r = bench_fn("adjoint_erk_step dopri5", warm2, iters2, || {
        adjoint_erk_step(tab, &rhs, 0.0, 0.1, &u, &ks, &mut lambda, &mut gt, &mut aws);
    });
    record(r, &mut results);

    // GMRES on the implicit-step operator
    let mut x = vec![0.0f32; n];
    let mut jw = vec![0.0f32; n];
    let r = bench_fn("gmres (I - h/2 J) solve", warm2, iters2, || {
        x.fill(0.0);
        gmres(
            |w, out| {
                rhs.jvp(0.3, &u, w, &mut jw);
                for i in 0..n {
                    out[i] = w[i] - 0.05 * jw[i];
                }
            },
            &v,
            &mut x,
            &GmresOptions::default(),
        );
    });
    record(r, &mut results);

    // checkpoint store ops
    use pnode::checkpoint::{CheckpointStore, StepCheckpoint};
    let r = bench_fn("checkpoint insert+remove (6 stages)", 5, 20, || {
        let mut store = CheckpointStore::new();
        for step in 0..16 {
            store.insert(StepCheckpoint {
                step,
                t: 0.0,
                h: 0.1,
                u: u.clone(),
                ks: Some(ks.clone()),
            });
        }
        for step in (0..16).rev() {
            store.remove(step);
        }
    });
    record(r, &mut results);

    // facade hot path: one Session reused across iterations (workspace
    // reuse is what the serving path pays for)
    {
        use pnode::api::SolverBuilder;
        let spec = SolverBuilder::new()
            .scheme_str("dopri5")
            .uniform(4)
            .build()
            .expect("valid micro spec");
        let lam = vec![1.0f32; n];
        let r = pnode::bench::bench_grad(
            "session.grad (dopri5, nt=4)",
            &spec,
            &rhs,
            &u,
            &lam,
            warm2,
            iters2,
        );
        record(r, &mut results);
    }

    // XLA artifact path (if built)
    if let (Ok(client), Ok(manifest)) =
        (pnode::runtime::Client::cpu(), pnode::runtime::Manifest::load_default())
    {
        if let Ok(arts) =
            pnode::runtime::ModelArtifacts::load(&client, &manifest, "clf_d64")
        {
            let entry = arts.entry.clone();
            let mut rng2 = Rng::new(2);
            let theta = pnode::nn::init::kaiming_uniform(&mut rng2, &entry.dims, 1.0);
            let xrhs = pnode::ode::XlaRhs::new(arts, theta).unwrap();
            let nx = xrhs.state_len();
            let mut ux = vec![0.0f32; nx];
            rng2.fill_normal(&mut ux);
            let mut ox = vec![0.0f32; nx];
            let mut gx = vec![0.0f32; xrhs.param_len()];
            let r = bench_fn("XLA clf_d64 f", warm, iters, || {
                xrhs.f(0.3, &ux, &mut ox);
            });
            record(r, &mut results);
            let vx = ox.clone();
            let r = bench_fn("XLA clf_d64 vjp_both", warm, iters, || {
                xrhs.vjp_both(0.3, &ux, &vx, &mut ox, &mut gx);
            });
            record(r, &mut results);
        }
    } else {
        println!("(XLA artifacts not available; skipped PJRT micro-benches)");
    }

    // BENCH_micro.json is a perf *trajectory*, not a snapshot: entries
    // are keyed (name, build tag) and accumulate across PRs; re-running
    // the same build replaces its own entries instead of duplicating
    // them, and an unreadable existing file degrades to a fresh history
    use pnode::util::json::Json;
    let build = pnode::obs::build_tag();
    let path = "BENCH_micro.json";
    let mut entries: Vec<Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| pnode::util::json::parse(&t).ok())
        .and_then(|j| j.as_arr().map(|a| a.to_vec()))
        .unwrap_or_default();
    let fresh: Vec<&str> = results.iter().map(|r| r.name.as_str()).collect();
    entries.retain(|e| {
        let same_build = e.get("build").and_then(Json::as_str) == Some(build.as_str());
        let name = e.get("name").and_then(Json::as_str).unwrap_or("");
        !(same_build && fresh.contains(&name))
    });
    for r in &results {
        let mut kv = vec![("build".to_string(), Json::str(build.clone()))];
        if let Some(obj) = r.to_json().as_obj() {
            kv.extend(obj.iter().cloned());
        }
        entries.push(Json::Obj(kv));
    }
    let total = entries.len();
    match std::fs::write(path, Json::Arr(entries).to_string_pretty()) {
        Ok(()) => println!(
            "appended {} entries (build {build}) to {path} ({total} total)",
            results.len()
        ),
        Err(e) => println!("(could not write {path}: {e})"),
    }
}
