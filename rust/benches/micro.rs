//! Hot-path microbenchmarks (the §Perf profile base): ERK step, adjoint
//! step, VJP through the pure-Rust MLP and (if built) the XLA artifacts,
//! GMRES iteration, checkpoint store ops.
//!
//! Besides the human-readable summaries, every result is appended to
//! `BENCH_micro.json` at the repo root (cargo runs benches from the
//! workspace root) so perf trends are machine-diffable across commits.

use pnode::adjoint::discrete_erk::{adjoint_erk_step, AdjointErkWorkspace};
use pnode::bench::{bench_fn, BenchResult};
use pnode::linalg::gmres::{gmres, GmresOptions};
use pnode::nn::Act;
use pnode::ode::erk::{erk_step, ErkWorkspace};
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau;
use pnode::util::rng::Rng;

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let mut record = |r: BenchResult, results: &mut Vec<BenchResult>| {
        println!("{}", r.summary());
        results.push(r);
    };

    let mut rng = Rng::new(1);
    // paper-scale RHS: 65-168-168-64, batch 128
    let dims = vec![65, 168, 168, 64];
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let rhs = ModuleRhs::mlp(dims, Act::Relu, true, 128, theta);
    let n = rhs.state_len();
    let mut u = vec![0.0f32; n];
    rng.fill_normal(&mut u);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v);
    let mut out = vec![0.0f32; n];
    let mut gt = vec![0.0f32; rhs.param_len()];

    let r = bench_fn("mlp.f (B=128, 65-168-168-64)", 2, 10, || {
        rhs.f(0.3, &u, &mut out);
    });
    record(r, &mut results);
    let r = bench_fn("mlp.vjp_both", 2, 10, || {
        rhs.vjp_both(0.3, &u, &v, &mut out, &mut gt);
    });
    record(r, &mut results);
    let r = bench_fn("mlp.jvp", 2, 10, || {
        rhs.jvp(0.3, &u, &v, &mut out);
    });
    record(r, &mut results);

    let tab = &tableau::DOPRI5;
    let mut ks: Vec<Vec<f32>> = (0..tab.s).map(|_| vec![0.0f32; n]).collect();
    let mut un = vec![0.0f32; n];
    let mut ews = ErkWorkspace::new(n);
    let r = bench_fn("erk_step dopri5", 2, 10, || {
        erk_step(tab, &rhs, 0.0, 0.1, &u, &mut ks, &mut un, &mut ews, None);
    });
    record(r, &mut results);

    let mut aws = AdjointErkWorkspace::new(tab.s, n);
    let mut lambda = v.clone();
    let r = bench_fn("adjoint_erk_step dopri5", 1, 5, || {
        adjoint_erk_step(tab, &rhs, 0.0, 0.1, &u, &ks, &mut lambda, &mut gt, &mut aws);
    });
    record(r, &mut results);

    // GMRES on the implicit-step operator
    let mut x = vec![0.0f32; n];
    let mut jw = vec![0.0f32; n];
    let r = bench_fn("gmres (I - h/2 J) solve", 1, 5, || {
        x.fill(0.0);
        gmres(
            |w, out| {
                rhs.jvp(0.3, &u, w, &mut jw);
                for i in 0..n {
                    out[i] = w[i] - 0.05 * jw[i];
                }
            },
            &v,
            &mut x,
            &GmresOptions::default(),
        );
    });
    record(r, &mut results);

    // checkpoint store ops
    use pnode::checkpoint::{CheckpointStore, StepCheckpoint};
    let r = bench_fn("checkpoint insert+remove (6 stages)", 5, 20, || {
        let mut store = CheckpointStore::new();
        for step in 0..16 {
            store.insert(StepCheckpoint {
                step,
                t: 0.0,
                h: 0.1,
                u: u.clone(),
                ks: Some(ks.clone()),
            });
        }
        for step in (0..16).rev() {
            store.remove(step);
        }
    });
    record(r, &mut results);

    // facade hot path: one Session reused across iterations (workspace
    // reuse is what the serving path pays for)
    {
        use pnode::api::SolverBuilder;
        let spec = SolverBuilder::new()
            .scheme_str("dopri5")
            .uniform(4)
            .build()
            .expect("valid micro spec");
        let lam = vec![1.0f32; n];
        let r = pnode::bench::bench_grad(
            "session.grad (dopri5, nt=4)",
            &spec,
            &rhs,
            &u,
            &lam,
            1,
            5,
        );
        record(r, &mut results);
    }

    // XLA artifact path (if built)
    if let (Ok(client), Ok(manifest)) =
        (pnode::runtime::Client::cpu(), pnode::runtime::Manifest::load_default())
    {
        if let Ok(arts) =
            pnode::runtime::ModelArtifacts::load(&client, &manifest, "clf_d64")
        {
            let entry = arts.entry.clone();
            let mut rng2 = Rng::new(2);
            let theta = pnode::nn::init::kaiming_uniform(&mut rng2, &entry.dims, 1.0);
            let xrhs = pnode::ode::XlaRhs::new(arts, theta).unwrap();
            let nx = xrhs.state_len();
            let mut ux = vec![0.0f32; nx];
            rng2.fill_normal(&mut ux);
            let mut ox = vec![0.0f32; nx];
            let mut gx = vec![0.0f32; xrhs.param_len()];
            let r = bench_fn("XLA clf_d64 f", 2, 10, || {
                xrhs.f(0.3, &ux, &mut ox);
            });
            record(r, &mut results);
            let vx = ox.clone();
            let r = bench_fn("XLA clf_d64 vjp_both", 2, 10, || {
                xrhs.vjp_both(0.3, &ux, &vx, &mut ox, &mut gx);
            });
            record(r, &mut results);
        }
    } else {
        println!("(XLA artifacts not available; skipped PJRT micro-benches)");
    }

    let json =
        pnode::util::json::Json::Arr(results.iter().map(|r| r.to_json()).collect());
    match std::fs::write("BENCH_micro.json", json.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_micro.json ({} entries)", results.len()),
        Err(e) => println!("(could not write BENCH_micro.json: {e})"),
    }
}
