//! Auto-policy sweep (fig. 3 shape): `pnode:auto:<budget>` against the
//! hand-tuned checkpoint policies on the paper-sized classification model
//! (dims 65-168-168-64, batch 128, dopri5, N_t = 12).
//!
//! Asserts the ISSUE-8 acceptance triplet:
//!   * measured peak hot bytes of the auto run stay ≤ the budget,
//!   * auto's measured wall time is within 15% of the best hand-tuned
//!     policy that fits the budget,
//!   * the auto session's gradients are bitwise identical to a session
//!     running the resolved concrete policy directly.
//!
//! Flags: `--smoke` shrinks iteration counts for CI.  The ledger is
//! pointed at `target/auto_policy_ledger` before any session opens, so
//! resolution runs off whatever this bench itself has recorded (cold:
//! the documented priors) instead of the repo's `.pnode/ledger`.

use pnode::api::{Session, SolverBuilder};
use pnode::bench::{bench_grad, Table};
use pnode::coordinator::Runner;
use pnode::methods::MemModel;
use pnode::nn::Act;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau::Scheme;
use pnode::ode::ModuleRhs;
use pnode::util::rng::Rng;

/// 1.5 MiB: admits binomial:4 (1 MiB hot) but not `all` (~2.75 MiB at
/// N_t = 12, s+1 = 8 stage vectors per step) on the 32 KiB state below.
const BUDGET: u64 = 1_572_864;
const NT: usize = 12;

fn main() {
    // before any Session: resolution reads the default ledger directory
    std::env::set_var("PNODE_LEDGER_DIR", "target/auto_policy_ledger");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (warm, iters) = if smoke { (1usize, 3usize) } else { (2, 8) };

    const D: usize = 64;
    const B: usize = 128;
    let dims = vec![D + 1, 168, 168, D];
    let mut rng = Rng::new(9);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let rhs = ModuleRhs::mlp(dims.clone(), Act::Relu, true, B, theta);
    let mut u0 = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut u0);
    let lambda0 = vec![1.0f32; rhs.state_len()];
    let s = Scheme::Dopri5.tableau().s as u64;

    let hand_tuned = [
        "all",
        "solution_only",
        "binomial:2",
        "binomial:4",
        "tiered:1572864:target/auto_policy_spill",
    ];
    let auto_str = format!("auto:{BUDGET}");

    let mut runner = Runner::new("auto_policy");
    let mut table = Table::new(
        "auto:<budget> vs hand-tuned checkpoint policies (dopri5, N_t = 12)",
        &["policy", "mean (s)", "min (s)", "peak hot bytes", "fits budget"],
    );

    let spec_for = |policy: &str| {
        SolverBuilder::new()
            .policy_str(policy)
            .scheme(Scheme::Dopri5)
            .uniform(NT)
            .build()
            .unwrap_or_else(|e| panic!("{policy}: {e}"))
    };

    // measured wall time + measured peak hot bytes per policy; the best
    // budget-fitting hand-tuned mean is the 15% yardstick for auto
    let mut best_fitting: Option<(String, f64)> = None;
    let mut measure = |runner: &mut Runner, table: &mut Table, policy: &str| -> f64 {
        let spec = spec_for(policy);
        let mm = MemModel::for_rhs(&rhs, s, NT as u64, 1);
        let r = bench_grad(policy, &spec, &rhs, &u0, &lambda0, warm, iters);
        let row = runner.run_spec_job("spiral_clf", &spec, mm.ckpt_bytes_for(&spec.method), || {
            let mut session = Session::new(spec.clone()).expect("spec validated at build");
            session.grad(&rhs, &u0, &lambda0).report
        });
        // tiered runs count spilled bytes in measured_ckpt_bytes; their
        // RAM residency is the hot-tier peak (0 for non-tiered policies)
        let peak_hot = if row.ckpt_hot_bytes > 0 {
            row.ckpt_hot_bytes
        } else {
            row.measured_ckpt_bytes
        };
        let fits = peak_hot <= BUDGET;
        table.row(vec![
            policy.into(),
            format!("{:.4}", r.mean_secs),
            format!("{:.4}", r.min_secs),
            peak_hot.to_string(),
            fits.to_string(),
        ]);
        let better = best_fitting.as_ref().map_or(true, |(_, b)| r.mean_secs < *b);
        if fits && better {
            best_fitting = Some((policy.to_string(), r.mean_secs));
        }
        r.mean_secs
    };

    for policy in hand_tuned {
        measure(&mut runner, &mut table, policy);
    }
    let auto_mean = measure(&mut runner, &mut table, &auto_str);
    table.print();

    // --- budget + resolution + bitwise assertions -----------------------
    let auto_spec = spec_for(&auto_str);
    let mut auto_session = Session::new(auto_spec).expect("auto spec builds");
    let out = auto_session.grad(&rhs, &u0, &lambda0);
    let peak_hot = if out.report.tier.peak_hot_bytes > 0 {
        out.report.tier.peak_hot_bytes
    } else {
        out.report.ckpt_bytes
    };
    assert!(
        peak_hot <= BUDGET,
        "auto run peak hot bytes {peak_hot} exceed the budget {BUDGET}"
    );
    let resolved = auto_session
        .resolved_policy()
        .expect("auto specs always record a resolution")
        .clone();
    println!(
        "\nauto:{} resolved to {} (requested {:?})",
        pnode::checkpoint::MemoryBudget::from_bytes(BUDGET).display(),
        resolved.name(),
        out.report.auto.requested_name(),
    );

    let direct_spec = SolverBuilder::new()
        .policy_str(&resolved.name())
        .scheme(Scheme::Dopri5)
        .uniform(NT)
        .build()
        .expect("resolved policy is concrete and valid");
    let mut direct = Session::new(direct_spec).expect("direct spec builds");
    let direct_out = direct.grad(&rhs, &u0, &lambda0);
    assert_eq!(out.u_f, direct_out.u_f, "forward states diverge");
    assert_eq!(
        auto_session.grad_theta(),
        direct.grad_theta(),
        "auto vs direct grad_theta must be bitwise identical"
    );
    assert_eq!(
        auto_session.lambda0(),
        direct.lambda0(),
        "auto vs direct lambda0 must be bitwise identical"
    );

    let (best_name, best_mean) =
        best_fitting.expect("at least one hand-tuned policy fits the budget");
    println!(
        "auto mean {:.4}s vs best fitting hand-tuned {best_name} {:.4}s ({:+.1}%)",
        auto_mean,
        best_mean,
        100.0 * (auto_mean / best_mean - 1.0)
    );
    assert!(
        auto_mean <= 1.15 * best_mean,
        "auto mean {auto_mean:.4}s is more than 15% over the best \
         budget-fitting hand-tuned policy {best_name} ({best_mean:.4}s)"
    );

    let path = runner.save().expect("save results");
    println!("rows saved to {path:?} (total {:.1}s)", runner.elapsed_secs());
    println!("auto policy OK: budget respected, within 15% of best, gradients bitwise equal");
}
