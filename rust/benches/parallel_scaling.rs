//! Parallel-scaling bench: worker sweep over the data-parallel adjoint
//! engine + the shared-budget fleet demo.
//!
//! Demonstrates the two engine guarantees end to end:
//! (a) gradients are **bitwise identical** for `workers = 1, 2, N`
//!     (asserted hard on every sweep point), and
//! (b) N concurrent shard sweeps share ONE global hot-tier budget
//!     through the arbiter — the over-subscribed fleet finishes with
//!     spills while its concurrent hot footprint stays ≤ the budget
//!     (asserted via the arbiter counters that land in the JSON rows).
//!
//! Rows: `target/bench_results/parallel_scaling.json` (workers,
//! samples_per_sec, lease counters per row).  Flags: `--smoke` shrinks
//! the problem, `--assert-scaling` requires samples_per_sec to improve
//! with workers (skipped on single-core machines);
//! `PNODE_BENCH_FULL=1` widens the sweep.

use std::time::Instant;

use pnode::api::{Session, SolverBuilder};
use pnode::bench::Table;
use pnode::checkpoint::CheckpointPolicy;
use pnode::coordinator::{JobBody, JobMeta, Runner};
use pnode::methods::MethodReport;
use pnode::nn::Act;
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::util::rng::Rng;

const SHARD_ROWS: usize = 16;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let assert_scaling = argv.iter().any(|a| a == "--assert-scaling");
    let full = std::env::var("PNODE_BENCH_FULL").is_ok();
    let (batch, nt, reps) = if full {
        (512usize, 48usize, 3usize)
    } else if smoke {
        (256, 16, 3)
    } else {
        (256, 32, 2)
    };

    let d = 16usize;
    let dims = vec![d + 1, 96, 96, d];
    let mut rng = Rng::new(17);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let rhs = ModuleRhs::mlp(dims, Act::Tanh, true, batch, theta);
    let mut u0 = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut u0);
    let mut w = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut w);

    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut sweep = vec![1usize, 2, 4];
    if full {
        sweep.push(8);
    }
    println!(
        "parallel_scaling: batch {batch} x {nt} steps (RK4), dims {:?}, \
         {} shards of {SHARD_ROWS} rows, {avail} cores available",
        [d + 1, 96, 96, d],
        batch.div_ceil(SHARD_ROWS),
    );

    // the whole sweep is one spec family: policy × workers
    let spec_with = |policy: CheckpointPolicy, workers: usize| {
        SolverBuilder::new()
            .policy(policy)
            .scheme_str("rk4")
            .uniform(nt)
            .workers(workers)
            .shard_rows(SHARD_ROWS)
            .build()
            .expect("valid parallel spec")
    };
    // one full gradient; returns (λ, θ̄, report, best seconds over reps)
    let grad_with = |policy: CheckpointPolicy,
                     workers: usize|
     -> (Vec<f32>, Vec<f32>, MethodReport, f64) {
        let mut best = f64::INFINITY;
        let mut out = None;
        for _ in 0..reps {
            let mut session =
                Session::new(spec_with(policy.clone(), workers)).expect("valid spec");
            let t = Instant::now();
            let rep = session.grad(&rhs, &u0, &w).report;
            let secs = t.elapsed().as_secs_f64();
            if secs < best {
                best = secs;
                out = Some((session.lambda0().to_vec(), session.grad_theta().to_vec(), rep));
            }
        }
        let (lam, g, rep) = out.expect("reps >= 1");
        (lam, g, rep, best)
    };

    // ---- (a) worker sweep: scaling with hard bitwise identity ----
    let mut runner = Runner::new("parallel_scaling");
    let mut table = Table::new(
        "Worker scaling — one gradient, batch sharded across the pool",
        &["workers", "time/grad (s)", "samples/s", "speedup", "bitwise vs w=1"],
    );
    let mut sps = Vec::new();
    let mut base: Option<(Vec<f32>, Vec<f32>, f64)> = None;
    for &workers in &sweep {
        let (lam, g, rep, secs) = grad_with(CheckpointPolicy::All, workers);
        let throughput = batch as f64 / secs;
        runner.run_job("mlp_17_96_96_16", "pnode-parallel", "rk4", nt, 0, || rep);
        let (speedup, bitwise) = match &base {
            None => {
                base = Some((lam, g, secs));
                (1.0, "—".to_string())
            }
            Some((lam1, g1, secs1)) => {
                assert_eq!(&lam, lam1, "λ must be bitwise identical at workers={workers}");
                assert_eq!(&g, g1, "θ̄ must be bitwise identical at workers={workers}");
                (secs1 / secs, "yes".into())
            }
        };
        table.row(vec![
            workers.to_string(),
            format!("{secs:.4}"),
            format!("{throughput:.0}"),
            format!("{speedup:.2}x"),
            bitwise,
        ]);
        sps.push((workers, throughput));
    }
    table.print();

    // ---- (b) shared-budget fleet: spill, don't OOM ----
    let footprint = {
        let (_, _, rep, _) = grad_with(CheckpointPolicy::All, 1);
        rep.ckpt_bytes
    };
    let budget = (footprint / 4).max(1);
    let spill_dir = std::env::temp_dir().join(format!("pnode-parscale-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_dir);
    let tiered = CheckpointPolicy::Tiered {
        budget_bytes: budget,
        dir: spill_dir.to_string_lossy().into_owned(),
        compress_f16: false,
        inner: Box::new(CheckpointPolicy::All),
    };
    let fleet_workers = 4usize;
    let (lam_t, g_t, rep_t, secs_t) = grad_with(tiered, fleet_workers);
    runner.run_job("mlp_17_96_96_16", "pnode-parallel-tiered", "rk4", nt, 0, || rep_t);
    let (lam_all, g_all, _, _) = grad_with(CheckpointPolicy::All, fleet_workers);
    assert_eq!(lam_t, lam_all, "spilling must never change λ");
    assert_eq!(g_t, g_all, "spilling must never change θ̄");
    assert!(rep_t.tier.spills > 0, "fleet at 1/4 budget must spill: {:?}", rep_t.tier);
    assert!(
        rep_t.exec.peak_leased_bytes <= budget,
        "fleet hot tier exceeded the global budget: peak {} > {budget}",
        rep_t.exec.peak_leased_bytes
    );
    assert_eq!(rep_t.exec.over_grant_bytes, 0, "{:?}", rep_t.exec);
    println!(
        "\nfleet: {fleet_workers} workers, ONE {} hot-tier pool (all-resident footprint {}):\n\
         \x20 spills {}  prefetch hits {}  sync reads {}  lease waits {}  peak leased {} <= budget  \
         time/grad {secs_t:.4}s\n\
         \x20 gradients bitwise identical to the in-memory run.",
        pnode::util::human_bytes(budget),
        pnode::util::human_bytes(footprint),
        rep_t.tier.spills,
        rep_t.tier.prefetch_hits,
        rep_t.tier.cold_reads,
        rep_t.exec.lease_waits,
        pnode::util::human_bytes(rep_t.exec.peak_leased_bytes),
    );
    let _ = std::fs::remove_dir_all(&spill_dir);

    // ---- (c) the coordinator's job matrix on the worker pool ----
    let matrix_nts = [8usize, 12, 16];
    let jobs: Vec<(JobMeta, JobBody)> = matrix_nts
        .iter()
        .flat_map(|&nt| {
            [CheckpointPolicy::All, CheckpointPolicy::SolutionOnly].map(|policy| {
                let spec = SolverBuilder::new()
                    .policy(policy)
                    .scheme_str("rk4")
                    .uniform(nt)
                    .build()
                    .expect("valid matrix spec");
                let meta = JobMeta::from_spec("mlp_9_32_8", &spec, 0);
                let body: JobBody = Box::new(move || {
                    let dims = vec![9, 32, 8];
                    let mut rng = Rng::new(nt as u64);
                    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
                    let rhs = ModuleRhs::mlp(dims, Act::Tanh, true, 8, theta);
                    let mut u0 = vec![0.0f32; rhs.state_len()];
                    rng.fill_normal(&mut u0);
                    let lam = vec![1.0f32; rhs.state_len()];
                    let mut session = Session::new(spec).expect("spec validated at build");
                    session.grad(&rhs, &u0, &lam).report
                });
                (meta, body)
            })
        })
        .collect();
    let n_matrix = jobs.len();
    runner.run_jobs_parallel(fleet_workers.min(avail), jobs);
    println!("job matrix: {n_matrix} pure-Rust jobs executed on the worker pool");

    let path = runner.save().expect("save results");
    println!("rows saved to {path:?} (total {:.1}s)", runner.elapsed_secs());

    // ---- CI gate ----
    if assert_scaling {
        if avail < 2 {
            println!("--assert-scaling skipped: single-core machine");
            return;
        }
        let sps1 = sps.iter().find(|(w, _)| *w == 1).expect("w=1 in sweep").1;
        let best = sps
            .iter()
            .filter(|(w, _)| *w > 1 && *w <= avail.max(2))
            .map(|(_, s)| *s)
            .fold(0.0f64, f64::max);
        let ratio = best / sps1;
        println!(
            "scaling gate: best multi-worker {best:.0} vs single {sps1:.0} samples/s ({ratio:.2}x)"
        );
        if avail < 4 {
            // cramped machines schedule too noisily for a hard wall-clock
            // gate; report instead of flaking unrelated changes
            println!("--assert-scaling advisory only ({avail} cores < 4)");
            return;
        }
        assert!(
            ratio > 1.15,
            "parallel workers must beat one worker on this size: {ratio:.2}x"
        );
    }
}
