//! The Prop-2 memory/compute trade-off, measured: sweep the binomial
//! checkpoint budget N_c and report recomputed steps (executed vs DP
//! prediction vs the paper's closed form) and measured checkpoint bytes.
//! Each budget is the same facade spec with a different policy.
//!
//!     cargo run --release --example checkpoint_tradeoff [-- --nt 32]

use pnode::api::SolverBuilder;
use pnode::bench::Table;
use pnode::checkpoint::{prop2_extra_steps, BinomialPlanner, CheckpointPolicy};
use pnode::nn::Act;
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::util::cli::Args;
use pnode::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let nt = args.get_usize("nt", 24);

    let dims = vec![9, 24, 8];
    let mut rng = Rng::new(9);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let rhs = ModuleRhs::mlp(dims, Act::Tanh, true, 16, theta);
    let mut u0 = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut u0);
    let lambda0 = vec![1.0f32; rhs.state_len()];

    let mut table = Table::new(
        &format!("Checkpoint budget trade-off (RK4, N_t={nt})"),
        &["N_c", "recomputed (executed)", "DP", "Prop. 2", "ckpt bytes", "time (ms)"],
    );
    let mut planner = BinomialPlanner::new();
    for nc in [1usize, 2, 3, 4, 6, 8, 12, 16, nt - 1] {
        let mut session = SolverBuilder::new()
            .policy(CheckpointPolicy::Binomial { n_checkpoints: nc })
            .scheme_str("rk4")
            .uniform(nt)
            .session()
            .expect("valid binomial spec");
        let t = std::time::Instant::now();
        let out = session.grad(&rhs, &u0, &lambda0);
        let ms = t.elapsed().as_secs_f64() * 1e3;
        table.row(vec![
            nc.to_string(),
            out.report.recompute_steps.to_string(),
            planner.optimal_cost(nt, nc).to_string(),
            prop2_extra_steps(nt, nc).map(|v| v.to_string()).unwrap_or("-".into()),
            out.report.ckpt_bytes.to_string(),
            format!("{ms:.2}"),
        ]);
    }
    table.print();
    println!(
        "\nPNODE-All (N_c >= N_t-1) recomputes nothing; the budget knob trades\n\
         memory for the DP-optimal number of re-executed steps (DESIGN.md §5)."
    );
}
