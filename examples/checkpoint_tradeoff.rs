//! The Prop-2 memory/compute trade-off, measured: sweep the binomial
//! checkpoint budget N_c and report recomputed steps (executed vs DP
//! prediction vs the paper's closed form) and measured checkpoint bytes.
//!
//!     cargo run --release --example checkpoint_tradeoff [-- --nt 32]

use pnode::bench::Table;
use pnode::checkpoint::{prop2_extra_steps, BinomialPlanner, CheckpointPolicy};
use pnode::methods::{BlockSpec, GradientMethod, Pnode};
use pnode::nn::Act;
use pnode::ode::rhs::{MlpRhs, OdeRhs};
use pnode::ode::tableau::Scheme;
use pnode::util::cli::Args;
use pnode::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let nt = args.get_usize("nt", 24);

    let dims = vec![9, 24, 8];
    let mut rng = Rng::new(9);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let rhs = MlpRhs::new(dims, Act::Tanh, true, 16, theta);
    let mut u0 = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut u0);
    let lambda0 = vec![1.0f32; rhs.state_len()];
    let spec = BlockSpec::new(Scheme::Rk4, nt);

    let mut table = Table::new(
        &format!("Checkpoint budget trade-off (RK4, N_t={nt})"),
        &["N_c", "recomputed (executed)", "DP", "Prop. 2", "ckpt bytes", "time (ms)"],
    );
    let mut planner = BinomialPlanner::new();
    for nc in [1usize, 2, 3, 4, 6, 8, 12, 16, nt - 1] {
        let mut m = Pnode::new(CheckpointPolicy::Binomial { n_checkpoints: nc });
        let t = std::time::Instant::now();
        m.forward(&rhs, &spec, &u0);
        let mut lambda = lambda0.clone();
        let mut grad = vec![0.0f32; rhs.param_len()];
        m.backward(&rhs, &spec, &mut lambda, &mut grad);
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let r = m.report();
        table.row(vec![
            nc.to_string(),
            r.recompute_steps.to_string(),
            planner.optimal_cost(nt, nc).to_string(),
            prop2_extra_steps(nt, nc).map(|v| v.to_string()).unwrap_or("-".into()),
            r.ckpt_bytes.to_string(),
            format!("{ms:.2}"),
        ]);
    }
    table.print();
    println!(
        "\nPNODE-All (N_c >= N_t-1) recomputes nothing; the budget knob trades\n\
         memory for the DP-optimal number of re-executed steps (DESIGN.md §5)."
    );
}
