//! Classification demo (paper §5.1 surrogate): 4 ODE blocks + readout on
//! the spiral dataset, comparing the gradient methods' speed/memory and
//! the continuous-adjoint accuracy gap with ReLU dynamics (Fig. 2's
//! phenomenon, at laptop scale).  Each method is one `RunSpec` built
//! through the facade.
//!
//!     cargo run --release --example classification [-- --steps 60]

use pnode::api::SolverBuilder;
use pnode::bench::Table;
use pnode::data::spiral::SpiralDataset;
use pnode::nn::{Act, Adam, Optimizer};
use pnode::ode::ModuleRhs;
use pnode::tasks::ClassificationTask;
use pnode::util::cli::Args;
use pnode::util::rng::Rng;

const D: usize = 16;
const B: usize = 64;

fn run(method: &str, steps: usize, seed: u64) -> (f64, f64, f64) {
    let mut rng = Rng::new(seed);
    let dims = vec![D + 1, 32, D];
    let p = pnode::nn::param_count(&dims);
    let dims_i = dims.clone();
    let spec = SolverBuilder::new()
        .method_str(method)
        .scheme_str("rk4")
        .uniform(4)
        .build()
        .unwrap_or_else(|e| panic!("{method}: {e}"));
    let mut task = ClassificationTask::new(&mut rng, 4, &spec, p, D, 4, move |r| {
        pnode::nn::init::kaiming_uniform(r, &dims_i, 1.0)
    });
    // ReLU dynamics: the irreversibility that breaks the continuous adjoint
    let mut rhs = ModuleRhs::mlp(dims, Act::Relu, true, B, task.block_theta(0).to_vec());
    let ds = SpiralDataset::generate(&mut rng, 300, 4, D);
    let (train, test) = ds.split(0.9);
    let mut opt = Adam::new(task.theta.len(), 3e-3);
    let mut x = vec![0.0f32; B * D];
    let mut y = vec![0usize; B];
    let t0 = std::time::Instant::now();
    for it in 0..steps {
        train.fill_batch(it * B, B, &mut x, &mut y);
        let res = task.grad_step(&mut rhs, B, &x, &y, 0.05);
        let g = res.grad;
        task.apply_grad(&mut opt as &mut dyn Optimizer, &g);
    }
    let secs = t0.elapsed().as_secs_f64();
    let mut xt = vec![0.0f32; B * D];
    let mut yt = vec![0usize; B];
    test.fill_batch(0, B, &mut xt, &mut yt);
    let (loss, acc) = task.evaluate(&mut rhs, B, &xt, &yt);
    (loss, acc, secs)
}

fn main() {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 80);
    let mut table = Table::new(
        "Classification (4 ODE blocks, ReLU dynamics, RK4) — Fig. 2 shape",
        &["method", "test loss", "test acc", "train time (s)"],
    );
    for method in ["pnode", "pnode2", "aca", "anode", "naive", "cont"] {
        let (loss, acc, secs) = run(method, steps, 7);
        table.row(vec![
            method.into(),
            format!("{loss:.4}"),
            format!("{acc:.3}"),
            format!("{secs:.2}"),
        ]);
        eprintln!("{method}: done in {secs:.2}s");
    }
    table.print();
    println!(
        "\nExpected shape (paper Fig. 2): the reverse-accurate methods reach\n\
         comparable accuracy; the continuous adjoint (cont) trails with ReLU\n\
         dynamics; pnode is the fastest reverse-accurate method."
    );
}
