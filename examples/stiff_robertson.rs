//! Stiff dynamics (paper §5.3): learn Robertson's chemistry.
//! Crank–Nicolson (implicit, enabled by PNODE's high-level adjoint) learns
//! the dynamics; adaptive Dopri5's gradients explode (Fig. 5 / Table 8).
//!
//!     cargo run --release --example stiff_robertson [-- --epochs 200]

use pnode::data::robertson::RobertsonData;
use pnode::nn::{Act, AdamW, Optimizer};
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::ode::tableau::Scheme;
use pnode::tasks::StiffTask;
use pnode::train::GradStats;
use pnode::util::cli::Args;
use pnode::util::rng::Rng;

fn train(task: &StiffTask, explicit: bool, epochs: usize) -> (f64, GradStats, f64, f64) {
    let dims = vec![3, 24, 24, 24, 3];
    let mut rng = Rng::new(5);
    let mut theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 0.05);
    let mut rhs = ModuleRhs::mlp(dims, Act::Gelu, false, 1, theta.clone());
    let mut opt = AdamW::new(theta.len(), 5e-3, 1e-4);
    let mut stats = GradStats::default();
    let mut loss = f64::NAN;
    let mut nfe_f = 0.0;
    let mut nfe_b = 0.0;
    let t0 = std::time::Instant::now();
    for _ in 0..epochs {
        let step = if explicit {
            task.grad_explicit_adaptive(&rhs, 1e-6)
        } else {
            task.grad_implicit(&rhs, Scheme::CrankNicolson)
        };
        loss = step.loss;
        nfe_f += step.nfe_forward as f64;
        nfe_b += step.nfe_backward as f64;
        let gn = pnode::train::grad_norm(&step.grad);
        stats.observe(gn, 1e5);
        if !gn.is_finite() {
            break; // exploded
        }
        let mut g = step.grad;
        pnode::train::clip_grad_norm(&mut g, 50.0);
        opt.step(&mut theta, &g);
        rhs.set_params(&theta);
    }
    let secs = t0.elapsed().as_secs_f64() / epochs as f64;
    (loss, stats, secs, (nfe_f + nfe_b) / epochs as f64)
}

fn main() {
    let args = Args::from_env();
    let epochs = args.get_usize("epochs", 150);
    // min–max scaled data (paper §5.3.1) — without it the tiny species is
    // invisible to the loss
    let data = RobertsonData::generate(40, 6, true);
    let task = StiffTask::new(data, 2);

    println!("training with Crank–Nicolson (implicit, PNODE discrete adjoint)...");
    let (mae_cn, stats_cn, secs_cn, nfe_cn) = train(&task, false, epochs);
    println!("training with adaptive Dopri5 (explicit baseline)...");
    let (mae_ex, stats_ex, secs_ex, nfe_ex) = train(&task, true, epochs);

    let mut t = pnode::bench::Table::new(
        "Robertson stiff dynamics (Table 8 / Fig. 5 shape)",
        &["integrator", "final MAE", "max |grad|", "exploded", "NFE/iter", "s/iter"],
    );
    t.row(vec![
        "Crank–Nicolson".into(),
        format!("{mae_cn:.5}"),
        format!("{:.2e}", stats_cn.max_norm),
        stats_cn.exploded.to_string(),
        format!("{nfe_cn:.0}"),
        format!("{secs_cn:.3}"),
    ]);
    t.row(vec![
        "Dopri5 (adaptive)".into(),
        format!("{mae_ex:.5}"),
        format!("{:.2e}", stats_ex.max_norm),
        stats_ex.exploded.to_string(),
        format!("{nfe_ex:.0}"),
        format!("{secs_ex:.3}"),
    ]);
    t.print();
    println!(
        "\nExpected shape: CN trains stably to low MAE; the explicit method\n\
         shows much larger gradient norms (explosion) and/or higher NFE."
    );
}
