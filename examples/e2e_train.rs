//! END-TO-END VALIDATION DRIVER (DESIGN.md §7, recorded in EXPERIMENTS.md).
//!
//! Trains the full classification system — 4 neural-ODE blocks × 50,296
//! params = 201,184 trainable parameters (paper budget: 199,800) — for a
//! few hundred optimizer steps on the spiral surrogate, through the REAL
//! production stack: Pallas-kernel HLO artifacts → PJRT runtime → Dopri5 →
//! PNODE discrete adjoint with checkpointing → Adam.  Logs the loss curve.
//!
//!     make artifacts && cargo run --release --example e2e_train
//!     (add `-- --no-xla` to run on the pure-Rust mirror instead)

use pnode::api::SolverBuilder;
use pnode::data::spiral::SpiralDataset;
use pnode::nn::{Act, Adam, Optimizer};
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::tasks::ClassificationTask;
use pnode::util::cli::Args;
use pnode::util::rng::Rng;

const D: usize = 64;
const B: usize = 128;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 200);
    let use_xla = !args.flag("no-xla");
    let nt = args.get_usize("nt", 2);
    let mut rng = Rng::new(123);

    let dims = vec![D + 1, 168, 168, D];
    let per_block = pnode::nn::param_count(&dims);
    let dims_i = dims.clone();
    let spec = SolverBuilder::new()
        .method_str("pnode")
        .scheme_str("dopri5")
        .uniform(nt)
        .build()
        .map_err(|e| anyhow::anyhow!(e))?;
    let mut task = ClassificationTask::new(&mut rng, 4, &spec, per_block, D, 10, move |r| {
        pnode::nn::init::kaiming_uniform(r, &dims_i, 1.0)
    });
    println!(
        "e2e: 4 ODE blocks x {per_block} = {} params (paper: 199,800), \
         Dopri5 N_t={nt}, batch {B}",
        4 * per_block
    );

    let mut rhs: Box<dyn OdeRhs> = if use_xla {
        let client = pnode::runtime::Client::cpu()?;
        let manifest = pnode::runtime::Manifest::load_default()?;
        let arts = pnode::runtime::ModelArtifacts::load(&client, &manifest, "clf_d64")?;
        println!("backend: XLA/PJRT artifacts (Pallas dense kernel inside)");
        Box::new(pnode::ode::XlaRhs::new(arts, task.block_theta(0).to_vec())?)
    } else {
        println!("backend: pure-Rust mirror");
        Box::new(ModuleRhs::mlp(dims, Act::Relu, true, B, task.block_theta(0).to_vec()))
    };

    let ds = SpiralDataset::generate(&mut rng, 800, 10, D);
    let (train, test) = ds.split(0.9);
    let mut opt = Adam::new(task.theta.len(), args.get_f64("lr", 1e-3));
    let mut log = pnode::train::TrainLog::new();
    let mut x = vec![0.0f32; B * D];
    let mut y = vec![0usize; B];

    let t0 = std::time::Instant::now();
    for step in 0..steps {
        train.fill_batch(step * B, B, &mut x, &mut y);
        let res = task.grad_step(rhs.as_mut(), B, &x, &y, 0.05);
        let gn = pnode::train::grad_norm(&res.grad);
        task.apply_grad(&mut opt as &mut dyn Optimizer, &res.grad);
        log.push(step, res.loss, Some(res.accuracy), gn, res.report.nfe_forward, res.report.nfe_backward);
        if step % 20 == 0 || step + 1 == steps {
            println!(
                "step {step:4}  loss {:.4}  acc {:.3}  |g| {:.2e}  ckpt {}",
                res.loss,
                res.accuracy,
                gn,
                pnode::util::human_bytes(res.report.ckpt_bytes)
            );
        }
    }
    let total = t0.elapsed().as_secs_f64();

    let mut xt = vec![0.0f32; B * D];
    let mut yt = vec![0usize; B];
    test.fill_batch(0, B, &mut xt, &mut yt);
    let (tl, ta) = task.evaluate(rhs.as_mut(), B, &xt, &yt);
    println!("\n=== E2E SUMMARY ===");
    println!("steps: {steps}, total {total:.1}s ({:.3}s/step)", total / steps as f64);
    println!(
        "loss: {:.4} -> {:.4} (best {:.4})",
        log.rows.first().unwrap().loss,
        log.rows.last().unwrap().loss,
        log.best_loss()
    );
    println!("test loss {tl:.4}, test acc {ta:.3}");
    let out = "target/e2e_train_log.csv";
    std::fs::create_dir_all("target").ok();
    std::fs::write(out, log.to_csv())?;
    println!("loss curve written to {out}");
    Ok(())
}
