//! Tiered checkpoint storage demo: solve an `N_t` sweep whose checkpoint
//! footprint exceeds the RAM budget, spilling to disk and prefetching back
//! during the adjoint sweep — at near-in-memory speed, with gradients
//! bitwise-identical to the all-resident backend (uncompressed path).
//!
//!     cargo run --release --example tiered_spill [-- --nt 1024 --budget 1m]

use pnode::api::SolverBuilder;
use pnode::bench::Table;
use pnode::checkpoint::CheckpointPolicy;
use pnode::nn::Act;
use pnode::ode::ModuleRhs;
use pnode::ode::rhs::OdeRhs;
use pnode::util::cli::Args;
use pnode::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let nt = args.get_usize("nt", 1024);
    let budget_spec = args.get_or("budget", "1m").to_string();
    let budget = pnode::checkpoint::MemoryBudget::parse(&budget_spec)
        .expect("bad --budget (e.g. 512k, 1m)");

    let dims = vec![17, 32, 16];
    let mut rng = Rng::new(7);
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);
    let rhs = ModuleRhs::mlp(dims, Act::Tanh, true, 8, theta);
    let mut u0 = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut u0);
    let lambda0 = vec![1.0f32; rhs.state_len()];

    let spill_dir = std::env::temp_dir().join(format!("pnode-tiered-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_dir);

    // every configuration is the same spec with a different policy
    let run = |policy: CheckpointPolicy| {
        let mut session = SolverBuilder::new()
            .policy(policy)
            .scheme_str("rk4")
            .uniform(nt)
            .session()
            .expect("valid tiered spec");
        let t = std::time::Instant::now();
        let out = session.grad(&rhs, &u0, &lambda0);
        (
            out.report,
            t.elapsed().as_secs_f64(),
            session.lambda0().to_vec(),
            session.grad_theta().to_vec(),
        )
    };

    let (r_mem, t_mem, l_mem, g_mem) = run(CheckpointPolicy::All);
    let tiered = |f16: bool| CheckpointPolicy::Tiered {
        budget_bytes: budget.bytes,
        dir: spill_dir.to_string_lossy().into_owned(),
        compress_f16: f16,
        inner: Box::new(CheckpointPolicy::All),
    };
    let (r_t, t_t, l_t, g_t) = run(tiered(false));
    let (r_h, t_h, _, _) = run(tiered(true));

    let mut table = Table::new(
        &format!(
            "Tiered checkpoint storage (RK4, N_t={nt}, RAM budget {})",
            pnode::util::human_bytes(budget.bytes)
        ),
        &["backend", "peak RAM", "cold written", "spills", "prefetch hits", "sync reads", "time (s)"],
    );
    for (name, r, secs) in [
        ("in-memory", &r_mem, t_mem),
        ("tiered f32", &r_t, t_t),
        ("tiered f16", &r_h, t_h),
    ] {
        table.row(vec![
            name.into(),
            pnode::util::human_bytes(r.tier.peak_hot_bytes),
            pnode::util::human_bytes(r.tier.cold_bytes_written),
            r.tier.spills.to_string(),
            r.tier.prefetch_hits.to_string(),
            r.tier.cold_reads.to_string(),
            format!("{secs:.3}"),
        ]);
    }
    table.print();

    assert!(
        r_mem.ckpt_bytes > budget.bytes,
        "footprint {} must exceed the budget {} for this demo — raise --nt",
        r_mem.ckpt_bytes,
        budget.bytes
    );
    assert!(r_t.tier.spills > 0, "tiered run must spill");
    assert_eq!(l_t, l_mem, "λ: tiered (f32) is bitwise identical to in-memory");
    assert_eq!(g_t, g_mem, "θ̄: tiered (f32) is bitwise identical to in-memory");
    println!(
        "\ncheckpoint footprint {} vs RAM budget {}: {}x over budget, \
         gradients bitwise identical, slowdown {:.2}x",
        pnode::util::human_bytes(r_mem.ckpt_bytes),
        pnode::util::human_bytes(budget.bytes),
        r_mem.ckpt_bytes / budget.bytes.max(1),
        t_t / t_mem.max(1e-9),
    );
    println!(
        "f16 cold tier: {} written ({:.2}x smaller), max |err| {:.2e} over {} elems",
        pnode::util::human_bytes(r_h.tier.cold_bytes_written),
        r_t.tier.cold_bytes_written as f64 / r_h.tier.cold_bytes_written.max(1) as f64,
        r_h.tier.compress_max_abs_err,
        r_h.tier.compressed_elems,
    );
    let _ = std::fs::remove_dir_all(&spill_dir);
}
