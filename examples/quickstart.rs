//! Quickstart: the smallest end-to-end PNODE gradient.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Loads the `quick_d8` AOT artifacts (Pallas dense kernel inside), runs an
//! RK4 forward pass through the PJRT runtime, and computes the discrete-
//! adjoint gradient of a scalar loss — then cross-checks against the pure-
//! Rust mirror. Falls back to the pure-Rust RHS when artifacts are missing.

use pnode::checkpoint::CheckpointPolicy;
use pnode::methods::{BlockSpec, GradientMethod, Pnode};
use pnode::nn::Act;
use pnode::ode::rhs::{MlpRhs, OdeRhs};
use pnode::ode::tableau::Scheme;
use pnode::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    let dims = vec![9, 16, 8];
    let theta = pnode::nn::init::kaiming_uniform(&mut rng, &dims, 1.0);

    // production path: AOT artifacts through PJRT
    let xla_rhs: Option<Box<dyn OdeRhs>> = (|| {
        let client = pnode::runtime::Client::cpu().ok()?;
        let manifest = pnode::runtime::Manifest::load_default().ok()?;
        let arts = pnode::runtime::ModelArtifacts::load(&client, &manifest, "quick_d8").ok()?;
        Some(Box::new(pnode::ode::XlaRhs::new(arts, theta.clone()).ok()?) as Box<dyn OdeRhs>)
    })();
    let rust_rhs = MlpRhs::new(dims, Act::Tanh, true, 4, theta);

    let n = rust_rhs.state_len();
    let mut u0 = vec![0.0f32; n];
    rng.fill_normal(&mut u0);
    // loss L = Σ u(T): λ_T = 1
    let lambda0 = vec![1.0f32; n];
    let spec = BlockSpec::new(Scheme::Rk4, 8);

    let gradient = |rhs: &dyn OdeRhs| {
        let mut method = Pnode::new(CheckpointPolicy::All);
        let uf = method.forward(rhs, &spec, &u0);
        let mut lambda = lambda0.clone();
        let mut grad = vec![0.0f32; rhs.param_len()];
        method.backward(rhs, &spec, &mut lambda, &mut grad);
        (uf, lambda, grad, method.report())
    };

    let (uf, lam, grad, report) = gradient(&rust_rhs);
    println!("u(T)[0..4]        = {:?}", &uf[..4]);
    println!("dL/du0[0..4]      = {:?}", &lam[..4]);
    println!("|dL/dθ|           = {:.4}", pnode::tensor::nrm2(&grad));
    println!(
        "NFE fwd/bwd       = {}/{},  ckpt {}",
        report.nfe_forward,
        report.nfe_backward,
        pnode::util::human_bytes(report.ckpt_bytes)
    );

    if let Some(xrhs) = xla_rhs {
        let (_, lam_x, grad_x, _) = gradient(xrhs.as_ref());
        println!(
            "XLA-vs-Rust agreement: λ rel-l2 {:.2e}, θ̄ rel-l2 {:.2e}",
            pnode::testing::rel_l2(&lam_x, &lam),
            pnode::testing::rel_l2(&grad_x, &grad)
        );
    } else {
        println!("(artifacts not built — ran pure-Rust mirror only)");
    }
    Ok(())
}
