//! Quickstart: the smallest end-to-end PNODE gradient, through the typed
//! `SolverBuilder` → `RunSpec` → `Session` facade.  This file matches the
//! README quickstart verbatim.
//!
//!     cargo run --release --example quickstart

use pnode::api::{ArchSpec, Session, SolverBuilder};
use pnode::nn::Act;
use pnode::ode::rhs::OdeRhs;
use pnode::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // one typed, serializable description of the run: solver AND dynamics
    let spec = SolverBuilder::new()
        .method_str("pnode") // discrete adjoint, checkpoint every step
        .scheme_str("rk4")
        .uniform(8) // 8 fixed steps over [0, 1]
        .arch(ArchSpec::ConcatMlp { hidden: vec![16], act: Act::Tanh }) // f(u, θ, t)
        .build()
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("spec:\n{}\n", spec.to_json().to_string_pretty());

    // the dynamics the spec declares: a time-conditioned MLP vector field
    // over batch 4 of 8-channel states
    let mut rng = Rng::new(42);
    let theta = spec.init_theta(&mut rng, 8).map_err(|e| anyhow::anyhow!(e))?;
    let rhs = spec.make_rhs(8, 4, theta).map_err(|e| anyhow::anyhow!(e))?;

    // a long-lived session: owns the engine and reusable workspaces
    let mut session = Session::new(spec).map_err(|e| anyhow::anyhow!(e))?;

    // loss L = Σ u(T)  =>  seed λ_T = 1
    let mut u0 = vec![0.0f32; rhs.state_len()];
    rng.fill_normal(&mut u0);
    let lambda_t = vec![1.0f32; rhs.state_len()];

    let out = session.grad(&rhs, &u0, &lambda_t);
    println!("u(T)[0..4]   = {:?}", &out.u_f[..4]);
    println!("dL/du0[0..4] = {:?}", &session.lambda0()[..4]);
    println!("|dL/dθ|      = {:.4}", pnode::tensor::nrm2(session.grad_theta()));
    println!(
        "NFE fwd/bwd  = {}/{},  ckpt {}",
        out.report.nfe_forward,
        out.report.nfe_backward,
        pnode::util::human_bytes(out.report.ckpt_bytes)
    );
    Ok(())
}
