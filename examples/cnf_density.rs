//! CNF density estimation (paper §5.2): FFJORD on the POWER surrogate —
//! through the AOT `cnf_power` artifacts when built, otherwise through
//! the XLA-free concatsquash module path (`ArchSpec::ConcatSquashMlp` →
//! `HutchinsonCnfRhs`, with the trace adjoint exact via the module
//! system's second-order pass).
//!
//!     cargo run --release --example cnf_density [-- --iters 20]
//!     make artifacts  # to exercise the XLA path instead

use pnode::api::{ArchSpec, SolverBuilder};
use pnode::data::tabular::TabularDataset;
use pnode::nn::{Act, Adam, Optimizer};
use pnode::ode::rhs::OdeRhs;
use pnode::ode::rhs_xla::XlaCnfRhs;
use pnode::tasks::{CnfTask, HutchinsonCnfRhs};
use pnode::util::cli::Args;
use pnode::util::rng::Rng;

#[allow(clippy::too_many_arguments)]
fn train<R: OdeRhs>(
    rng: &mut Rng,
    rhs: &mut R,
    mut reseed_eps: impl FnMut(&mut Rng, &mut R),
    b: usize,
    d: usize,
    p: usize,
    theta0: Vec<f32>,
    iters: usize,
) -> anyhow::Result<()> {
    let ds = TabularDataset::from_preset(rng, "power").unwrap();
    let spec = SolverBuilder::new()
        .scheme_str("dopri5")
        .uniform(4)
        .build()
        .map_err(|e| anyhow::anyhow!(e))?;
    let mut task = CnfTask::new(rng, 1, &spec, b, d, p, move |_r| theta0.clone());
    let mut opt = Adam::new(task.theta.len(), 1e-3);

    let mut x = vec![0.0f32; b * d];
    let mut first = None;
    for it in 0..iters {
        ds.fill_batch(it * b, b, &mut x);
        reseed_eps(rng, rhs);
        let res = task.grad_step(rhs, &x);
        if first.is_none() {
            first = Some(res.nll);
        }
        opt.step(&mut task.theta, &res.grad);
        println!(
            "iter {it:3}  NLL {:.4}  NFE {}/{}  ckpt {}",
            res.nll,
            res.report.nfe_forward,
            res.report.nfe_backward,
            pnode::util::human_bytes(res.report.ckpt_bytes)
        );
    }
    println!(
        "NLL {} -> improved over {} iterations (full training takes more)",
        first.unwrap(),
        iters
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let iters = args.get_usize("iters", 15);
    let mut rng = Rng::new(17);

    // XLA path when artifacts exist
    if let Ok(client) = pnode::runtime::Client::cpu() {
        if let Ok(manifest) = pnode::runtime::Manifest::load_default() {
            let arts = pnode::runtime::ModelArtifacts::load(&client, &manifest, "cnf_power")?;
            let entry = arts.entry.clone();
            let (b, d, p) = (entry.batch, entry.state_dim, entry.param_count);
            println!("FFJORD on POWER surrogate (XLA): d={d}, batch={b}, {p} params/flow");
            let theta0 = pnode::nn::init::kaiming_uniform(&mut rng, &entry.dims, 0.5);
            let mut rhs = XlaCnfRhs::new(arts, theta0.clone())?;
            let mut eps = vec![0.0f32; b * d];
            return train(
                &mut rng,
                &mut rhs,
                move |r, rhs: &mut XlaCnfRhs| {
                    r.fill_rademacher(&mut eps);
                    rhs.set_eps(&eps);
                },
                b,
                d,
                p,
                theta0,
                iters,
            );
        }
        eprintln!("artifacts missing: running the XLA-free concatsquash module path");
    }

    // module path: concatsquash dynamics at the dataset's dim
    let (b, d) = (64usize, 6usize); // POWER preset dim
    let arch = ArchSpec::ConcatSquashMlp { hidden: vec![32, 32], act: Act::Tanh };
    let p = arch.param_count(d);
    println!("FFJORD on POWER surrogate: arch {} — d={d}, batch={b}, {p} params/flow", arch.name());
    let theta0 = arch.init(&mut rng, d);
    let mut rhs = HutchinsonCnfRhs::new(&arch, b, d, theta0.clone(), &mut rng);
    train(&mut rng, &mut rhs, |_r, _rhs| {}, b, d, p, theta0, iters)
}
