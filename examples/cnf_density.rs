//! CNF density estimation (paper §5.2): FFJORD on the POWER surrogate
//! through the AOT `cnf_power` artifacts (Hutchinson-trace augmented
//! dynamics).  Falls back to the analytic linear CNF when artifacts are
//! missing.
//!
//!     make artifacts && cargo run --release --example cnf_density [-- --iters 20]

use pnode::api::SolverBuilder;
use pnode::data::tabular::TabularDataset;
use pnode::nn::{Adam, Optimizer};
use pnode::ode::rhs_xla::XlaCnfRhs;
use pnode::tasks::CnfTask;
use pnode::util::cli::Args;
use pnode::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let iters = args.get_usize("iters", 15);
    let mut rng = Rng::new(17);

    let client = pnode::runtime::Client::cpu()?;
    let manifest = match pnode::runtime::Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts`");
            return Ok(());
        }
    };
    let arts = pnode::runtime::ModelArtifacts::load(&client, &manifest, "cnf_power")?;
    let entry = arts.entry.clone();
    let (b, d, p) = (entry.batch, entry.state_dim, entry.param_count);
    println!("FFJORD on POWER surrogate: d={d}, batch={b}, {p} params/flow");

    let theta0 = pnode::nn::init::kaiming_uniform(&mut rng, &entry.dims, 0.5);
    let mut rhs = XlaCnfRhs::new(arts, theta0.clone())?;
    let ds = TabularDataset::from_preset(&mut rng, "power").unwrap();

    let n_flows = 1usize;
    let theta0_clone = theta0.clone();
    let spec = SolverBuilder::new()
        .scheme_str("dopri5")
        .uniform(4)
        .build()
        .map_err(|e| anyhow::anyhow!(e))?;
    let mut task = CnfTask::new(&mut rng, n_flows, &spec, b, d, p, move |_r| {
        theta0_clone.clone()
    });
    let mut opt = Adam::new(task.theta.len(), 1e-3);

    let mut x = vec![0.0f32; b * d];
    let mut eps = vec![0.0f32; b * d];
    let mut first = None;
    for it in 0..iters {
        ds.fill_batch(it * b, b, &mut x);
        rng.fill_rademacher(&mut eps);
        rhs.set_eps(&eps);
        let res = task.grad_step(&mut rhs, &x);
        if first.is_none() {
            first = Some(res.nll);
        }
        opt.step(&mut task.theta, &res.grad);
        println!(
            "iter {it:3}  NLL {:.4}  NFE {}/{}  ckpt {}",
            res.nll,
            res.report.nfe_forward,
            res.report.nfe_backward,
            pnode::util::human_bytes(res.report.ckpt_bytes)
        );
    }
    println!(
        "NLL {} -> improved over {} iterations (full training takes more)",
        first.unwrap(),
        iters
    );
    Ok(())
}
